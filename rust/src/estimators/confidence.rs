//! Confidence intervals for quantile-estimator distance estimates,
//! inverted from the explicit Lemma-3 tail bounds.
//!
//! The bounds state `Pr(d̂ ≥ (1+ε)d) ≤ exp(−kε²/G_R(ε))` and
//! `Pr(d̂ ≤ (1−ε)d) ≤ exp(−kε²/G_L(ε))`. Solving each side for the ε
//! that makes the bound equal δ/2 turns a point estimate d̂ into a
//! guaranteed-coverage interval `[d̂/(1+ε_R), d̂/(1−ε_L)]` — the
//! practitioner-facing form of "the bounds are tight because the
//! distribution is specified" (paper §2.3).

use super::tail_bounds::tail_constants;
use crate::numerics::roots::brent;

/// A two-sided confidence interval for the true distance d.
#[derive(Debug, Clone, Copy)]
pub struct ConfidenceInterval {
    pub lo: f64,
    pub hi: f64,
    /// The one-sided relative half-widths actually achieved.
    pub eps_right: f64,
    pub eps_left: f64,
}

/// Precomputed inverter for fixed (α, q, k, δ): solves the two ε's once,
/// then each interval is two multiplies.
#[derive(Debug, Clone, Copy)]
pub struct IntervalBuilder {
    inv_one_plus: f64,
    inv_one_minus: f64,
    eps_right: f64,
    eps_left: f64,
}

impl IntervalBuilder {
    /// Build for a quantile estimator with quantile `q` and `k` samples,
    /// targeting two-sided coverage `1 − delta`.
    ///
    /// Each side's ε solves `exp(−k ε² / G(ε)) = δ/2`. The right side
    /// always has a solution; the left side's deviation cannot exceed
    /// ε = 1 (d̂ ≥ 0), so if even ε → 1 keeps the bound above δ/2 the
    /// interval is capped at lo-multiplier ∞⁻¹ = open-ended below.
    pub fn new(alpha: f64, q: f64, k: usize, delta: f64) -> Self {
        assert!(delta > 0.0 && delta < 1.0);
        assert!(k >= 2);
        let target = (delta / 2.0).ln();
        // right side: h(ε) = −k ε²/G_R(ε) − ln(δ/2), decreasing in ε.
        let h_right = |eps: f64| {
            let g = tail_constants(alpha, q, eps).g_right;
            -(k as f64) * eps * eps / g - target
        };
        // Bracket: h(0+) = −target > 0; find hi with h < 0.
        let mut hi = 0.5;
        while h_right(hi) > 0.0 && hi < 1e6 {
            hi *= 2.0;
        }
        let eps_right = brent(&h_right, 1e-9, hi, 1e-10, 200);

        let h_left = |eps: f64| {
            let g = tail_constants(alpha, q, eps).g_left;
            -(k as f64) * eps * eps / g - target
        };
        let eps_left = if h_left(1.0 - 1e-9) > 0.0 {
            1.0 - 1e-9 // can't certify a lower bound tighter than 0
        } else {
            brent(&h_left, 1e-9, 1.0 - 1e-9, 1e-10, 200)
        };
        Self {
            inv_one_plus: 1.0 / (1.0 + eps_right),
            inv_one_minus: 1.0 / (1.0 - eps_left),
            eps_right,
            eps_left,
        }
    }

    /// Interval around a point estimate (two multiplies).
    ///
    /// If `d̂ ≥ (1+ε_R)d` w.p. ≤ δ/2, then `d ≥ d̂/(1+ε_R)` w.p. ≥ 1−δ/2;
    /// symmetrically above.
    #[inline]
    pub fn around(&self, d_hat: f64) -> ConfidenceInterval {
        ConfidenceInterval {
            lo: d_hat * self.inv_one_plus,
            hi: d_hat * self.inv_one_minus,
            eps_right: self.eps_right,
            eps_left: self.eps_left,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tables;
    use super::*;
    use crate::estimators::{OptimalQuantile, ScaleEstimator};
    use crate::numerics::Xoshiro256pp;
    use crate::stable::StableDist;

    #[test]
    fn interval_widens_as_k_shrinks_and_delta_tightens() {
        let alpha = 1.0;
        let q = tables::q_star(alpha);
        let wide = IntervalBuilder::new(alpha, q, 20, 0.05).around(1.0);
        let narrow = IntervalBuilder::new(alpha, q, 200, 0.05).around(1.0);
        assert!(narrow.hi - narrow.lo < wide.hi - wide.lo);
        let strict = IntervalBuilder::new(alpha, q, 200, 0.001).around(1.0);
        assert!(strict.hi - strict.lo > narrow.hi - narrow.lo);
    }

    #[test]
    fn interval_contains_estimate_and_orders() {
        let b = IntervalBuilder::new(1.5, tables::q_star(1.5), 100, 0.05);
        let ci = b.around(7.0);
        assert!(ci.lo < 7.0 && 7.0 < ci.hi);
        assert!(ci.lo > 0.0);
    }

    #[test]
    fn empirical_coverage_meets_guarantee() {
        // MC: the guaranteed 95% interval must cover the truth in at
        // least ~95% of replicates (it's conservative, so typically more).
        let alpha = 1.0;
        let k = 100;
        let q = tables::q_star(alpha);
        let builder = IntervalBuilder::new(alpha, q, k, 0.05);
        let est = OptimalQuantile::new(alpha, k);
        let dist = StableDist::new(alpha, 1.0);
        let mut rng = Xoshiro256pp::new(808);
        let mut buf = vec![0.0; k];
        let reps = 4_000;
        let mut covered = 0usize;
        for _ in 0..reps {
            dist.sample_into(&mut rng, &mut buf);
            let dh = est.estimate(&mut buf);
            let ci = builder.around(dh);
            if ci.lo <= 1.0 && 1.0 <= ci.hi {
                covered += 1;
            }
        }
        let cov = covered as f64 / reps as f64;
        assert!(cov >= 0.95, "coverage {cov}");
    }
}
