//! Cramér–Rao efficiencies (Fig 1): the ratio of the smallest possible
//! asymptotic variance (inverse Fisher information of the scale family)
//! to each estimator's asymptotic variance.
//!
//! For the scale family `f(x; d) = d^{−1/α} f(x d^{−1/α})`, the per-sample
//! Fisher information about d at d = 1 is
//!
//! ```text
//!   I₁ = (1/α²) · E[ (1 + z · ∂ log f(z)/∂z)² ],   z ~ S(α, 1)
//! ```
//!
//! so the CR lower bound is `Var ≥ d²/(k · I₁)` and the efficiency of an
//! estimator with `Var → V d²/k` is `1/(I₁ V)`.

use super::{
    tables, FractionalPower, GeometricMean, HarmonicMean, QuantileEstimator, ScaleEstimator,
};
use crate::numerics::quadrature::adaptive;
use crate::stable::StandardStable;

/// Which estimator a Fig-1 curve refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    GeometricMean,
    HarmonicMean,
    FractionalPower,
    OptimalQuantile,
    Median,
}

impl EstimatorKind {
    pub fn label(&self) -> &'static str {
        match self {
            Self::GeometricMean => "gm",
            Self::HarmonicMean => "hm",
            Self::FractionalPower => "fp",
            Self::OptimalQuantile => "oq",
            Self::Median => "median",
        }
    }

    /// Asymptotic variance factor V (Var → V d²/k) at this α, NaN where
    /// the estimator is undefined / has infinite variance.
    pub fn variance_factor(&self, alpha: f64) -> f64 {
        // k only affects finite-sample corrections, not V; use any k.
        let k = 64;
        match self {
            Self::GeometricMean => GeometricMean::new(alpha, k).asymptotic_variance_factor(),
            Self::HarmonicMean => {
                if alpha < 1.0 {
                    HarmonicMean::new(alpha, k).asymptotic_variance_factor()
                } else {
                    f64::NAN
                }
            }
            Self::FractionalPower => {
                FractionalPower::new(alpha, k).asymptotic_variance_factor()
            }
            Self::OptimalQuantile => {
                let q = tables::q_star(alpha);
                QuantileEstimator::new(alpha, k, q).asymptotic_variance_factor()
            }
            Self::Median => QuantileEstimator::median(alpha, k).asymptotic_variance_factor(),
        }
    }
}

/// Per-sample Fisher information about the scale parameter d, at d = 1.
///
/// Integrated in the *quantile domain*: with `z(u) = F⁻¹((1+u)/2)`,
///
/// ```text
///   I₁ = (1/α²) · 2∫_0^∞ s(z)² f(z) dz = (1/α²) ∫_0^1 s(z(u))² du,
///   s(z) = 1 + z · ∂log f/∂z
/// ```
///
/// which maps the heavy tail (z up to 10^80 for small α) into u → 1
/// where the integrand tends smoothly to α² (since z·dlogf → −(α+1)) —
/// a bounded integrand on [0,1] instead of an un-truncatable improper
/// one.
pub fn fisher_information(alpha: f64) -> f64 {
    use once_cell::sync::Lazy;
    use std::collections::HashMap;
    use std::sync::Mutex;
    static CACHE: Lazy<Mutex<HashMap<u64, f64>>> = Lazy::new(|| Mutex::new(HashMap::new()));
    let key = (alpha * 1e9).round() as u64;
    if let Some(&v) = CACHE.lock().unwrap().get(&key) {
        return v;
    }
    let v = fisher_information_uncached(alpha);
    CACHE.lock().unwrap().insert(key, v);
    v
}

fn fisher_information_uncached(alpha: f64) -> f64 {
    let std = StandardStable::new(alpha);
    let integrand = |u: f64| {
        let z = std.abs_quantile(u.clamp(1e-12, 1.0 - 1e-12));
        let s = 1.0 + z * std.dlogpdf(z);
        s * s
    };
    // Endpoint values are finite; keep nodes interior.
    let total = adaptive(&integrand, 1e-9, 1.0 - 1e-9, 1e-8);
    total / (alpha * alpha)
}

/// Cramér–Rao bound factor: smallest possible V (Var ≥ V_cr · d²/k).
pub fn cramer_rao_bound_factor(alpha: f64) -> f64 {
    1.0 / fisher_information(alpha)
}

/// One point of Fig 1: efficiency (in [0,1]) of `kind` at `alpha`.
pub fn efficiency(kind: EstimatorKind, alpha: f64) -> f64 {
    let v = kind.variance_factor(alpha);
    if !v.is_finite() {
        return f64::NAN;
    }
    cramer_rao_bound_factor(alpha) / v
}

/// A full Fig-1 curve over an α grid.
pub fn efficiency_curve(kind: EstimatorKind, alphas: &[f64]) -> Vec<(f64, f64)> {
    alphas
        .iter()
        .map(|&a| (a, efficiency(kind, a)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fisher_information_gaussian_closed_form() {
        // α = 2: d is the variance of N(0, d); I(d) = 1/(2d²) ⇒ I₁ = 1/2.
        let i1 = fisher_information(2.0);
        assert!((i1 - 0.5).abs() < 1e-3, "I1(2) = {i1}");
    }

    #[test]
    fn fisher_information_cauchy_closed_form() {
        // α = 1: scale family of Cauchy with scale γ = d; I_γ = 1/(2γ²)
        // ⇒ in d-parametrization (d = γ here since α=1) I₁ = 1/2.
        let i1 = fisher_information(1.0);
        assert!((i1 - 0.5).abs() < 1e-3, "I1(1) = {i1}");
    }

    #[test]
    fn efficiencies_are_probabilities() {
        for &alpha in &[0.3, 0.7, 1.0, 1.4, 1.8, 2.0] {
            for kind in [
                EstimatorKind::GeometricMean,
                EstimatorKind::FractionalPower,
                EstimatorKind::OptimalQuantile,
                EstimatorKind::Median,
            ] {
                let e = efficiency(kind, alpha);
                assert!(
                    e > 0.0 && e <= 1.0 + 1e-6,
                    "{} at alpha={alpha}: {e}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn fig1_orderings_hold() {
        // oq ≈ gm for α < 1; oq considerably better for α > 1;
        // oq < fp variance for 1 < α ≤ 1.8 i.e. eff_oq > eff_fp there.
        let e_oq_15 = efficiency(EstimatorKind::OptimalQuantile, 1.5);
        let e_gm_15 = efficiency(EstimatorKind::GeometricMean, 1.5);
        let e_fp_15 = efficiency(EstimatorKind::FractionalPower, 1.5);
        assert!(e_oq_15 > e_gm_15, "oq {e_oq_15} vs gm {e_gm_15}");
        assert!(e_oq_15 > e_fp_15, "oq {e_oq_15} vs fp {e_fp_15}");
        // fp beats oq near α = 2 (paper: fp is near-optimal there in
        // asymptotic variance).
        let e_oq_2 = efficiency(EstimatorKind::OptimalQuantile, 1.95);
        let e_fp_2 = efficiency(EstimatorKind::FractionalPower, 1.95);
        assert!(e_fp_2 > e_oq_2, "fp {e_fp_2} vs oq {e_oq_2} at 1.95");
    }

    #[test]
    fn hm_efficient_only_small_alpha() {
        let e_small = efficiency(EstimatorKind::HarmonicMean, 0.2);
        assert!(e_small > 0.5, "hm at 0.2: {e_small}");
        assert!(efficiency(EstimatorKind::HarmonicMean, 1.5).is_nan());
    }
}
