//! The six project-invariant rules. Each rule is a pure function from
//! a parsed [`SourceFile`] to diagnostics; the driver in `mod.rs`
//! decides which files each rule applies to and filters the result
//! through the allowlist.
//!
//! Rule ids are stable — CI output, the allowlist file, and the README
//! table all reference them:
//!
//! - `PL001` — every `unsafe` is immediately preceded by `// SAFETY:`
//! - `PL002` — `unsafe` only in allowlisted (audited) files
//! - `PL003` — no timing calls inside kernel hot-loop modules
//! - `PL004` — protocol tags/error codes registered in the
//!   `MIN_VERSION` tables and version-gated in the decoder
//! - `PL005` — no bare `unwrap()` / undocumented `expect` in server
//!   admission and hot-path modules
//! - `PL006` — `stat_entries()` keys unique, snake_case, and covered
//!   by a Prometheus exposition family

use super::scanner::{find_word, SourceFile};
use super::Diagnostic;
use std::collections::{BTreeMap, BTreeSet};

pub const PL001: &str = "PL001";
pub const PL002: &str = "PL002";
pub const PL003: &str = "PL003";
pub const PL004: &str = "PL004";
pub const PL005: &str = "PL005";
pub const PL006: &str = "PL006";

/// Kernel hot-loop modules: PR 7's tracing-budget rule pins spans to
/// stage boundaries, so the selection/popcount/fill inner loops must
/// never read a clock.
pub const KERNEL_MODULES: &[&str] = &[
    "estimators/quickselect.rs",
    "estimators/sign.rs",
    "estimators/batch.rs",
];

/// Server admission and hot-path modules where a panic tears down a
/// connection (or the whole event loop) instead of surfacing a typed
/// error.
pub const HOT_MODULES: &[&str] = &[
    "server/conn.rs",
    "server/listener.rs",
    "server/reactor.rs",
    "coordinator/mod.rs",
    "coordinator/backpressure.rs",
];

/// Does `path` (forward-slash normalized) end with one of `suffixes`?
pub fn applies(path: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| path.ends_with(s))
}

fn diag(rule: &'static str, sf: &SourceFile, line0: usize, message: String) -> Diagnostic {
    Diagnostic {
        rule,
        path: sf.path.clone(),
        line: line0 + 1,
        message,
    }
}

// ---- PL001 / PL002: unsafe hygiene ---------------------------------

/// PL001: each `unsafe` token (outside test modules) must carry a
/// `SAFETY:` comment — trailing on the same line, or in the contiguous
/// `//` comment block directly above (attribute lines may intervene,
/// blank lines may not).
pub fn safety_comments(sf: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (ln, code) in sf.code.iter().enumerate() {
        if sf.in_test[ln] || find_word(code, "unsafe").is_none() {
            continue;
        }
        let mut ok = sf.raw[ln].contains("SAFETY:");
        let mut j = ln;
        while !ok && j > 0 {
            j -= 1;
            let t = sf.raw[j].trim_start();
            if t.starts_with("//") {
                if t.contains("SAFETY:") {
                    ok = true;
                }
                continue;
            }
            if t.starts_with("#[") {
                continue;
            }
            break;
        }
        if !ok {
            out.push(diag(
                PL001,
                sf,
                ln,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".into(),
            ));
        }
    }
    out
}

/// PL002: a file containing `unsafe` (outside tests) must be pinned in
/// the allowlist — the driver suppresses this diagnostic for entries
/// like `PL002 rust/src/server/reactor.rs`. One diagnostic per file.
pub fn unsafe_allowlist(sf: &SourceFile) -> Vec<Diagnostic> {
    for (ln, code) in sf.code.iter().enumerate() {
        if !sf.in_test[ln] && find_word(code, "unsafe").is_some() {
            return vec![diag(
                PL002,
                sf,
                ln,
                "`unsafe` outside the allowlist (add `PL002 <path>` to lint_allow.txt)".into(),
            )];
        }
    }
    Vec::new()
}

// ---- PL003: kernel timing ------------------------------------------

/// PL003: no clock reads in the kernel hot-loop modules. Spans are
/// measured at stage boundaries (coordinator/listener), never inside
/// the selection/popcount inner loops.
pub fn kernel_timing(sf: &SourceFile) -> Vec<Diagnostic> {
    if !applies(&sf.path, KERNEL_MODULES) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (ln, code) in sf.code.iter().enumerate() {
        if sf.in_test[ln] {
            continue;
        }
        for token in ["Instant", "SystemTime"] {
            if find_word(code, token).is_some() {
                out.push(diag(
                    PL003,
                    sf,
                    ln,
                    format!("`{token}` in a kernel module (measure at stage boundaries)"),
                ));
            }
        }
        if code.contains(".elapsed(") {
            out.push(diag(
                PL003,
                sf,
                ln,
                "`.elapsed()` in a kernel module (measure at stage boundaries)".into(),
            ));
        }
    }
    out
}

// ---- PL004: protocol version-gate registry -------------------------

/// `u8` constants declared in the file: name → (value, 0-based line).
fn parse_u8_consts(sf: &SourceFile) -> BTreeMap<String, (u64, usize)> {
    let mut out = BTreeMap::new();
    for (ln, code) in sf.code.iter().enumerate() {
        if sf.in_test[ln] {
            continue;
        }
        let Some(at) = find_word(code, "const") else {
            continue;
        };
        let rest = &code[at + "const".len()..];
        let Some((name, tail)) = rest.split_once(':') else {
            continue;
        };
        let name = name.trim();
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            continue;
        }
        let Some((ty, value)) = tail.split_once('=') else {
            continue;
        };
        if ty.trim() != "u8" {
            continue;
        }
        let value = value.trim().trim_end_matches(';').trim();
        if let Some(v) = parse_u64(value) {
            out.insert(name.to_string(), (v, ln));
        }
    }
    out
}

fn parse_u64(tok: &str) -> Option<u64> {
    if let Some(hex) = tok.strip_prefix("0x").or_else(|| tok.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        tok.parse().ok()
    }
}

fn resolve(tok: &str, consts: &BTreeMap<String, (u64, usize)>) -> Option<u64> {
    parse_u64(tok).or_else(|| consts.get(tok).map(|&(v, _)| v))
}

/// Parse a `NAME: &[(A, B)] = &[ (a, b), … ];` table. Entries must sit
/// on one line each (rustfmt keeps ours that way). Returns the decl
/// line and the `(first, second, line)` triples.
fn parse_pair_array(sf: &SourceFile, name: &str) -> Option<(usize, Vec<(String, String, usize)>)> {
    let decl = sf
        .code
        .iter()
        .position(|l| find_word(l, name).is_some() && l.contains('='))?;
    let mut entries = Vec::new();
    for (ln, code) in sf.code.iter().enumerate().skip(decl) {
        // On the declaration line, skip past the `=` so the element
        // type tuple `&[(u8, u8)]` is not mistaken for an entry.
        let mut rest = if ln == decl {
            code.split_once('=').map(|(_, r)| r).unwrap_or("")
        } else {
            code.as_str()
        };
        while let Some(open) = rest.find('(') {
            let Some(close) = rest[open..].find(')') else {
                break;
            };
            let inner = &rest[open + 1..open + close];
            if let Some((a, b)) = inner.split_once(',') {
                let (a, b) = (a.trim().to_string(), b.trim().to_string());
                let ident_ok = |s: &str| {
                    !s.is_empty()
                        && s.chars()
                            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
                };
                if ident_ok(&a) && ident_ok(&b) {
                    entries.push((a, b, ln));
                }
            }
            rest = &rest[open + close + 1..];
        }
        if code.contains("];") {
            break;
        }
    }
    Some((decl, entries))
}

/// All identifier-ish tokens (including `A::B` paths) in `chunk`.
fn path_tokens(chunk: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in chunk.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            cur.push(c);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Every `<tokens> … if version < GATE` association in the decoder:
/// walks the joined stripped source for `version < IDENT` and collects
/// the `TAG_*` / `ErrorCode::*` / `QueryKind::*` tokens between the
/// previous statement boundary and the comparison.
fn parse_version_guards(
    joined: &str,
    consts: &BTreeMap<String, (u64, usize)>,
) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    let bytes = joined.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = joined[from..].find("version") {
        let at = from + rel;
        from = at + "version".len();
        let prev = if at == 0 { None } else { Some(bytes[at - 1]) };
        let before_ok = !prev.is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_');
        let end = at + "version".len();
        let after = joined[end..].trim_start();
        if !before_ok
            || !after.starts_with('<')
            || after.starts_with("<<")
            || after.starts_with("<=")
        {
            continue;
        }
        let gate_tok: String = after[1..]
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        let Some(gate) = resolve(&gate_tok, consts) else {
            continue;
        };
        // The arm's pattern / condition extends back to the previous
        // statement or match-arm boundary.
        let head = &joined[..at];
        let cut = [
            head.rfind(';').map(|p| p + 1),
            head.rfind('{').map(|p| p + 1),
            head.rfind('}').map(|p| p + 1),
            head.rfind("=>").map(|p| p + 2),
        ]
        .into_iter()
        .flatten()
        .max()
        .unwrap_or(0);
        for tok in path_tokens(&head[cut..]) {
            if tok.starts_with("TAG_")
                || tok.starts_with("ErrorCode::")
                || tok.starts_with("QueryKind::")
            {
                out.push((tok, gate));
            }
        }
    }
    out
}

/// PL004: the frame-tag and error-code `MIN_VERSION` registries are
/// complete and every gated entry has a matching `if version < …`
/// decoder arm, so a new tag can never ship without its pre-gate
/// `BadVersion` refusal.
pub fn protocol_registry(sf: &SourceFile) -> Vec<Diagnostic> {
    if !applies(&sf.path, &["server/protocol.rs"]) {
        return Vec::new();
    }
    let consts = parse_u8_consts(sf);
    let tags: Vec<(&String, u64, usize)> = consts
        .iter()
        .filter(|(n, _)| n.starts_with("TAG_"))
        .map(|(n, &(v, ln))| (n, v, ln))
        .collect();
    if tags.is_empty() {
        return Vec::new();
    }
    let base = consts
        .get("MIN_PROTOCOL_VERSION")
        .map(|&(v, _)| v)
        .unwrap_or(1);
    let mut out = Vec::new();
    let Some((reg_line, entries)) = parse_pair_array(sf, "FRAME_TAG_MIN_VERSION") else {
        out.push(diag(
            PL004,
            sf,
            tags[0].2,
            "frame tags declared but no FRAME_TAG_MIN_VERSION registry table".into(),
        ));
        return out;
    };
    let mut registered: BTreeMap<String, u64> = BTreeMap::new();
    for (tag, min_tok, ln) in &entries {
        if !consts.contains_key(tag) {
            out.push(diag(
                PL004,
                sf,
                *ln,
                format!("registry entry `{tag}` does not name a declared tag constant"),
            ));
            continue;
        }
        let Some(min) = resolve(min_tok, &consts) else {
            out.push(diag(
                PL004,
                sf,
                *ln,
                format!("registry entry `{tag}`: cannot resolve minimum version `{min_tok}`"),
            ));
            continue;
        };
        if registered.insert(tag.clone(), min).is_some() {
            out.push(diag(
                PL004,
                sf,
                *ln,
                format!("duplicate registry entry for `{tag}`"),
            ));
        }
    }
    for (name, _, ln) in &tags {
        if !registered.contains_key(*name) {
            out.push(diag(
                PL004,
                sf,
                *ln,
                format!("frame tag `{name}` missing from the FRAME_TAG_MIN_VERSION registry"),
            ));
        }
    }
    // Guards and variant references are read from non-test code only:
    // a `version < …` comparison inside a test must not satisfy (or
    // pollute) the decoder-gate cross-check.
    let joined: String = sf
        .code
        .iter()
        .enumerate()
        .map(|(ln, l)| if sf.in_test[ln] { "" } else { l.as_str() })
        .collect::<Vec<_>>()
        .join("\n");
    let guards = parse_version_guards(&joined, &consts);
    for (name, min) in &registered {
        if *min > base && !guards.iter().any(|(t, g)| t == name && g == min) {
            out.push(diag(
                PL004,
                sf,
                reg_line,
                format!("tag `{name}` (since v{min}) has no `version < …` decoder gate"),
            ));
        }
    }
    // Error-code twin: every `ErrorCode::X` variant the file matches on
    // must be registered, and registered gated codes must be refused
    // by the decoder under pre-gate version stamps.
    let variants: BTreeSet<String> = path_tokens(&joined)
        .into_iter()
        .filter(|t| match t.strip_prefix("ErrorCode::") {
            Some(v) => v.chars().next().is_some_and(|c| c.is_ascii_uppercase()),
            None => false,
        })
        .collect();
    match parse_pair_array(sf, "ERROR_CODE_MIN_VERSION") {
        Some((ereg_line, eentries)) => {
            let mut eregistered: BTreeMap<String, u64> = BTreeMap::new();
            for (code, min_tok, ln) in &eentries {
                let Some(min) = resolve(min_tok, &consts) else {
                    out.push(diag(
                        PL004,
                        sf,
                        *ln,
                        format!("registry entry `{code}`: unresolved min version `{min_tok}`"),
                    ));
                    continue;
                };
                eregistered.insert(code.clone(), min);
            }
            for v in &variants {
                if !eregistered.contains_key(v) {
                    out.push(diag(
                        PL004,
                        sf,
                        ereg_line,
                        format!("error code `{v}` missing from ERROR_CODE_MIN_VERSION"),
                    ));
                }
            }
            for (code, min) in &eregistered {
                if *min > base && !guards.iter().any(|(t, g)| t == code && g == min) {
                    out.push(diag(
                        PL004,
                        sf,
                        ereg_line,
                        format!(
                            "error code `{code}` (since v{min}) has no `version < …` decoder gate"
                        ),
                    ));
                }
            }
        }
        None if !variants.is_empty() => {
            out.push(diag(
                PL004,
                sf,
                reg_line,
                "error codes declared but no ERROR_CODE_MIN_VERSION registry table".into(),
            ));
        }
        None => {}
    }
    out
}

// ---- PL005: hot-path unwrap hygiene --------------------------------

/// PL005: in admission/hot-path modules, `.unwrap()` is banned and
/// `.expect(…)` must document the violated contract with a literal
/// message starting `invariant:`. `unwrap_or*` combinators are fine.
pub fn bare_unwrap(sf: &SourceFile) -> Vec<Diagnostic> {
    if !applies(&sf.path, HOT_MODULES) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (ln, code) in sf.code.iter().enumerate() {
        if sf.in_test[ln] {
            continue;
        }
        let chars: Vec<char> = code.chars().collect();
        let raw: Vec<char> = sf.raw[ln].chars().collect();
        let mut i = 0usize;
        while let Some(at) = find_from(&chars, i, ".unwrap") {
            i = at + ".unwrap".len();
            // `.unwrap_or`, `.unwrap_or_else`, … are combinators and
            // fine; only the panicking nullary form is banned.
            if chars.get(i) == Some(&'(') {
                out.push(diag(
                    PL005,
                    sf,
                    ln,
                    "`.unwrap()` in a hot-path module — use `.expect(\"invariant: …\")`".into(),
                ));
            }
        }
        let mut j = 0usize;
        while let Some(at) = find_from(&chars, j, ".expect(") {
            j = at + ".expect(".len();
            // `code` and `raw` are char-aligned (stripping blanks one
            // char per char), so the literal can be read from `raw` at
            // the same index.
            let mut k = j;
            while k < raw.len() && raw[k].is_whitespace() {
                k += 1;
            }
            let arg: String = if k < raw.len() {
                raw[k..].iter().collect()
            } else {
                // Argument wrapped to the next line by rustfmt.
                match sf.raw.get(ln + 1) {
                    Some(l) => l.trim_start().to_string(),
                    None => String::new(),
                }
            };
            if !arg.starts_with("\"invariant:") {
                out.push(diag(
                    PL005,
                    sf,
                    ln,
                    "`.expect(…)` without an `\"invariant: …\"` contract message".into(),
                ));
            }
        }
    }
    out
}

/// Find `needle` in `haystack[from..]` (chars), returning the absolute
/// index of the match start.
fn find_from(haystack: &[char], from: usize, needle: &str) -> Option<usize> {
    let needle: Vec<char> = needle.chars().collect();
    if haystack.len() < needle.len() {
        return None;
    }
    (from..=haystack.len() - needle.len()).find(|&s| haystack[s..s + needle.len()] == needle[..])
}

// ---- PL006: metrics key hygiene ------------------------------------

const QUANTILE_SUFFIXES: &[&str] = &["_p50_ns", "_p95_ns", "_p99_ns"];
const SCAN_KINDS: &[&str] = &["oq", "gm", "fp", "median", "sign"];
const SCAN_FAMILY: &str = "stablesketch_scan_latency_ns";

/// PL006: `stat_entries()` keys must be unique, snake_case, and each
/// must map to a `stablesketch_*` Prometheus family literal in the
/// same file (quantile keys map to their histogram family; per-kind
/// scan quantiles to the labelled `scan_latency_ns` family).
pub fn metrics_keys(sf: &SourceFile) -> Vec<Diagnostic> {
    if !applies(&sf.path, &["metrics.rs"]) {
        return Vec::new();
    }
    let Some(start) = sf.code.iter().position(|l| l.contains("fn stat_entries")) else {
        return Vec::new();
    };
    // Brace-track the function body on the stripped view.
    let mut depth = 0i64;
    let mut started = false;
    let mut end = start;
    'outer: for (ln, line) in sf.code.iter().enumerate().skip(start) {
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            end = ln;
            break 'outer;
        }
    }
    let families: BTreeSet<&str> = sf
        .nontest_literals()
        .map(|(_, s)| s.as_str())
        .filter(|s| s.starts_with("stablesketch_"))
        .collect();
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut out = Vec::new();
    for (ln, key) in sf
        .literals
        .iter()
        .filter(|(ln, _)| (start..=end).contains(ln))
        .map(|(ln, s)| (*ln, s.as_str()))
    {
        if !seen.insert(key) {
            out.push(diag(PL006, sf, ln, format!("duplicate stat key `{key}`")));
        }
        let snake = !key.is_empty()
            && key.chars().next().is_some_and(|c| c.is_ascii_lowercase())
            && key
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
        if !snake {
            out.push(diag(PL006, sf, ln, format!("stat key `{key}` is not snake_case")));
        }
        if !key_covered(key, &families) {
            out.push(diag(
                PL006,
                sf,
                ln,
                format!("stat key `{key}` has no Prometheus exposition family in this file"),
            ));
        }
    }
    out
}

fn key_covered(key: &str, families: &BTreeSet<&str>) -> bool {
    for suf in QUANTILE_SUFFIXES {
        if let Some(base) = key.strip_suffix(suf) {
            let family = match base.strip_prefix("scan_") {
                Some(kind) if SCAN_KINDS.contains(&kind) => SCAN_FAMILY.to_string(),
                _ => format!("stablesketch_{base}_ns"),
            };
            return families.contains(family.as_str());
        }
    }
    families.contains(format!("stablesketch_{key}").as_str())
        || families.contains(format!("stablesketch_{key}_total").as_str())
}
