//! A lightweight lexical model of one Rust source file, built without
//! `syn` or any proc-macro machinery (the crate is std-only and the
//! lint must not grow the dependency tree).
//!
//! The scanner produces three parallel views of the file:
//!
//! - `raw`: the original lines, used for comment-content checks
//!   (`// SAFETY:` detection) and for reading `expect("…")` messages;
//! - `code`: the same lines with comments and string/char literal
//!   *contents* blanked out (delimiters kept), so token searches like
//!   `unsafe` or `.unwrap()` can never match inside a comment or a
//!   string;
//! - `literals`: every string literal's content with the line it
//!   starts on, for the metrics key/family cross-check.
//!
//! A per-line `in_test` mask marks `#[cfg(test)] mod … { … }` bodies —
//! test code exercises panics and unwraps on purpose and is exempt
//! from every rule.

/// One parsed source file. Lines are 0-indexed internally; diagnostics
/// render them 1-based.
pub struct SourceFile {
    /// Display path (repo-relative, forward slashes).
    pub path: String,
    /// Original source lines.
    pub raw: Vec<String>,
    /// Comment- and literal-stripped lines (same line count as `raw`).
    pub code: Vec<String>,
    /// String literal contents: (0-based start line, content).
    pub literals: Vec<(usize, String)>,
    /// True for lines inside a `#[cfg(test)] mod` body.
    pub in_test: Vec<bool>,
}

/// Accumulates the stripped text, tracking the current line so literal
/// starts can be recorded without a second pass.
struct Stripped {
    code: String,
    line: usize,
}

impl Stripped {
    /// Append one consumed char: newlines always survive (the line
    /// structure must match `raw`), everything else is kept verbatim
    /// (`keep`) or blanked to a space.
    fn push(&mut self, c: char, keep: bool) {
        if c == '\n' {
            self.line += 1;
            self.code.push('\n');
        } else {
            self.code.push(if keep { c } else { ' ' });
        }
    }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

impl SourceFile {
    pub fn parse(path: String, text: &str) -> SourceFile {
        let chars: Vec<char> = text.chars().collect();
        let mut out = Stripped {
            code: String::with_capacity(text.len()),
            line: 0,
        };
        let mut literals: Vec<(usize, String)> = Vec::new();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            // Line comment (covers `///` and `//!` doc forms too).
            if c == '/' && chars.get(i + 1) == Some(&'/') {
                while i < chars.len() && chars[i] != '\n' {
                    out.push(chars[i], false);
                    i += 1;
                }
                continue;
            }
            // Block comment; Rust block comments nest.
            if c == '/' && chars.get(i + 1) == Some(&'*') {
                out.push('/', false);
                out.push('*', false);
                i += 2;
                let mut depth = 1u32;
                while i < chars.len() && depth > 0 {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        out.push('/', false);
                        out.push('*', false);
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        out.push('*', false);
                        out.push('/', false);
                        i += 2;
                    } else {
                        out.push(chars[i], false);
                        i += 1;
                    }
                }
                continue;
            }
            // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#.
            if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                let mut j = i + 1;
                if c == 'b' && chars.get(j) == Some(&'r') {
                    j += 1;
                }
                let mut hashes = 0usize;
                while chars.get(j) == Some(&'#') {
                    hashes += 1;
                    j += 1;
                }
                let is_raw = c == 'r' || (chars.get(i + 1) == Some(&'r'));
                if chars.get(j) == Some(&'"') && (is_raw || (c == 'b' && hashes == 0)) {
                    // Blank the prefix, keep the opening quote.
                    while i < j {
                        out.push(chars[i], false);
                        i += 1;
                    }
                    out.push('"', true);
                    i += 1;
                    let lit_line = out.line;
                    let mut lit = String::new();
                    while i < chars.len() {
                        if !is_raw && chars[i] == '\\' {
                            // Byte string: escapes as in normal strings.
                            lit.push(chars[i]);
                            out.push(chars[i], false);
                            i += 1;
                            if i < chars.len() {
                                lit.push(chars[i]);
                                out.push(chars[i], false);
                                i += 1;
                            }
                            continue;
                        }
                        if chars[i] == '"' {
                            // Raw strings close only on `"` + the same
                            // number of `#`s that opened them.
                            let closes = (0..hashes).all(|h| chars.get(i + 1 + h) == Some(&'#'));
                            if closes {
                                out.push('"', true);
                                i += 1;
                                for _ in 0..hashes {
                                    out.push('#', false);
                                    i += 1;
                                }
                                break;
                            }
                        }
                        lit.push(chars[i]);
                        out.push(chars[i], false);
                        i += 1;
                    }
                    literals.push((lit_line, lit));
                    continue;
                }
                // Plain identifier starting with r/b; fall through.
            }
            // Normal string literal.
            if c == '"' {
                out.push('"', true);
                i += 1;
                let lit_line = out.line;
                let mut lit = String::new();
                while i < chars.len() {
                    if chars[i] == '\\' {
                        lit.push(chars[i]);
                        out.push(chars[i], false);
                        i += 1;
                        if i < chars.len() {
                            lit.push(chars[i]);
                            out.push(chars[i], false);
                            i += 1;
                        }
                        continue;
                    }
                    if chars[i] == '"' {
                        out.push('"', true);
                        i += 1;
                        break;
                    }
                    lit.push(chars[i]);
                    out.push(chars[i], false);
                    i += 1;
                }
                literals.push((lit_line, lit));
                continue;
            }
            // Char literal vs lifetime: `'x'` / `'\n'` are literals,
            // `'static` is a lifetime and passes through untouched.
            if c == '\'' {
                let is_char = match chars.get(i + 1) {
                    Some('\\') => true,
                    Some(_) => chars.get(i + 2) == Some(&'\''),
                    None => false,
                };
                out.push('\'', true);
                i += 1;
                if is_char {
                    while i < chars.len() {
                        if chars[i] == '\\' {
                            out.push(chars[i], false);
                            i += 1;
                            if i < chars.len() {
                                out.push(chars[i], false);
                                i += 1;
                            }
                            continue;
                        }
                        if chars[i] == '\'' {
                            out.push('\'', true);
                            i += 1;
                            break;
                        }
                        out.push(chars[i], false);
                        i += 1;
                    }
                }
                continue;
            }
            out.push(c, true);
            i += 1;
        }

        let raw: Vec<String> = text.lines().map(str::to_string).collect();
        let mut code: Vec<String> = out.code.lines().map(str::to_string).collect();
        // `str::lines` drops a trailing newline's empty tail; pad the
        // shorter view so the two stay index-compatible.
        while code.len() < raw.len() {
            code.push(String::new());
        }
        let in_test = test_mask(&code);
        SourceFile {
            path,
            raw,
            code,
            literals,
            in_test,
        }
    }

    /// String literal contents on non-test lines.
    pub fn nontest_literals(&self) -> impl Iterator<Item = &(usize, String)> {
        self.literals
            .iter()
            .filter(|(ln, _)| !self.in_test.get(*ln).copied().unwrap_or(false))
    }
}

/// Does `line` contain `word` delimited by non-identifier chars on
/// both sides? Returns the byte offset of the first such match.
pub fn find_word(line: &str, word: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = line[from..].find(word) {
        let at = from + rel;
        let before_ok = at == 0 || {
            let b = bytes[at - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let end = at + word.len();
        let after_ok = end >= bytes.len() || {
            let b = bytes[end];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + word.len();
    }
    None
}

/// Mark the body of every `#[cfg(test)] mod … { … }` block. Works on
/// the stripped view, so braces in strings or comments cannot skew the
/// depth count.
fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut skip_floor: Option<i64> = None;
    for (ln, line) in code.iter().enumerate() {
        let start_depth = depth;
        let opens = line.matches('{').count() as i64;
        let closes = line.matches('}').count() as i64;
        depth += opens - closes;
        if let Some(floor) = skip_floor {
            mask[ln] = true;
            if depth <= floor {
                skip_floor = None;
            }
            continue;
        }
        if line.contains("#[cfg(test)]") {
            armed = true;
        }
        if armed && find_word(line, "mod").is_some() && line.contains('{') {
            mask[ln] = true;
            armed = false;
            if depth > start_depth {
                skip_floor = Some(start_depth);
            }
            continue;
        }
        // The cfg(test) attribute attached to something other than a
        // mod block (a use, a single fn): it governs only that item,
        // which the next statement terminator closes.
        if armed && !line.contains("#[cfg(test)]") && line.contains(';') {
            armed = false;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SourceFile {
        SourceFile::parse("test.rs".into(), src)
    }

    #[test]
    fn comments_and_strings_are_blanked_from_code_view() {
        let sf = parse(concat!(
            "let a = \"unsafe in a string\"; // unsafe in a comment\n",
            "/* unsafe in a block\n   spanning lines */ let b = 1;\n",
        ));
        assert!(find_word(&sf.code[0], "unsafe").is_none());
        assert!(find_word(&sf.code[1], "unsafe").is_none());
        assert!(sf.code[1].contains("let b = 1;"));
        assert_eq!(sf.literals[0].1, "unsafe in a string");
    }

    #[test]
    fn raw_strings_and_char_literals_are_handled() {
        let src = "let s = r#\"x \"quoted\" y\"#;\nlet c = '{'; let l: &'static str = \"\";\n";
        let sf = parse(src);
        assert_eq!(sf.literals[0].1, "x \"quoted\" y");
        // The brace inside the char literal must not skew depth counts.
        assert_eq!(sf.code[1].matches('{').count(), 0);
        assert!(sf.code[1].contains("'static"));
    }

    #[test]
    fn cfg_test_mod_bodies_are_masked() {
        let sf = parse(concat!(
            "fn real() { x.unwrap(); }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { y.unwrap(); }\n",
            "}\n",
            "fn after() {}\n",
        ));
        assert_eq!(
            sf.in_test,
            vec![false, false, true, true, true, false],
            "{:?}",
            sf.in_test
        );
    }

    #[test]
    fn word_boundaries_reject_identifier_substrings() {
        assert!(find_word("forbid(unsafe_code)", "unsafe").is_none());
        assert!(find_word("let x = unsafe { y };", "unsafe").is_some());
        assert!(find_word("modules", "mod").is_none());
    }
}
