//! `pallas-lint`: the in-repo static analysis layer.
//!
//! The system's correctness story rests on conventions that no
//! compiler pass checks — SAFETY comments on the three audited
//! `unsafe` sites, clock-free kernel inner loops (the tracing-budget
//! rule), version-gated protocol tags, invariant-documented panics in
//! the admission path, and stat-key ↔ Prometheus-family agreement.
//! This module turns each convention into a deny-by-default diagnostic
//! with `file:line: [PLnnn] message` output, enforced as a blocking CI
//! step via the `pallas-lint` binary (`cargo run --bin pallas-lint`).
//!
//! Everything is std-only and token-based: a lightweight scanner
//! ([`scanner`]) blanks comments and literals so rules ([`rules`])
//! match real tokens, never prose. Pinned exceptions live in
//! `rust/lint_allow.txt` as `<rule> <path>` lines — the audited
//! `unsafe` modules are the canonical entries; a new file introducing
//! `unsafe` must be allowlisted in the same PR that audits it.

// Enforced by pallas-lint (PL002) and re-stated to the compiler: this
// module (and its children) must stay free of unsafe code.
#![forbid(unsafe_code)]

pub mod rules;
pub mod scanner;

use scanner::SourceFile;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint finding. `line` is 1-based; `path` is repo-relative with
/// forward slashes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// Pinned exceptions: `<rule-id> <repo-relative-path>` per line, `#`
/// comments and blank lines ignored. An entry suppresses that rule's
/// diagnostics for that file — nothing wider.
#[derive(Debug, Default)]
pub struct Allowlist {
    entries: Vec<(String, String)>,
}

impl Allowlist {
    pub fn empty() -> Allowlist {
        Allowlist::default()
    }

    pub fn parse(text: &str) -> Allowlist {
        let entries = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .filter_map(|l| {
                let (rule, path) = l.split_once(char::is_whitespace)?;
                Some((rule.to_string(), path.trim().replace('\\', "/")))
            })
            .collect();
        Allowlist { entries }
    }

    /// Load from disk; a missing file is an empty allowlist.
    pub fn load(path: &Path) -> io::Result<Allowlist> {
        match fs::read_to_string(path) {
            Ok(text) => Ok(Allowlist::parse(&text)),
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(Allowlist::empty()),
            Err(e) => Err(e),
        }
    }

    pub fn allows(&self, rule: &str, path: &str) -> bool {
        for (r, p) in &self.entries {
            if r == rule && (p == path || path.ends_with(&format!("/{p}"))) {
                return true;
            }
        }
        false
    }
}

/// Run every rule over one file's source text. `path` decides rule
/// applicability (kernel modules, hot-path modules, `protocol.rs`,
/// `metrics.rs`) and appears verbatim in diagnostics.
pub fn lint_file(path: &str, text: &str, allow: &Allowlist) -> Vec<Diagnostic> {
    let sf = SourceFile::parse(path.replace('\\', "/"), text);
    let mut out = Vec::new();
    out.extend(rules::safety_comments(&sf));
    out.extend(rules::unsafe_allowlist(&sf));
    out.extend(rules::kernel_timing(&sf));
    out.extend(rules::protocol_registry(&sf));
    out.extend(rules::bare_unwrap(&sf));
    out.extend(rules::metrics_keys(&sf));
    out.retain(|d| !allow.allows(d.rule, &d.path));
    out
}

/// Result of a whole-tree run.
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files: usize,
    pub diags: Vec<Diagnostic>,
}

/// Lint the repository rooted at `root`: scans `rust/src/**/*.rs`
/// against the allowlist at `rust/lint_allow.txt`. Fixture trees and
/// integration tests are deliberately out of scope — the invariants
/// guard shipped code.
pub fn run_repo(root: &Path) -> io::Result<LintReport> {
    let allow = Allowlist::load(&root.join("rust").join("lint_allow.txt"))?;
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    walk(&src, &mut files)?;
    files.sort();
    let mut diags = Vec::new();
    for file in &files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(file)?;
        diags.extend(lint_file(&rel, &text, &allow));
    }
    Ok(LintReport {
        files: files.len(),
        diags,
    })
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(rel: &str) -> (String, String) {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let path = format!("rust/lint_fixtures/{rel}");
        let text = match fs::read_to_string(root.join(&path)) {
            Ok(t) => t,
            Err(e) => panic!("reading fixture {path}: {e}"),
        };
        (path, text)
    }

    fn rules_hit(rel: &str) -> Vec<&'static str> {
        let (path, text) = fixture(rel);
        let mut rules: Vec<&'static str> = lint_file(&path, &text, &Allowlist::empty())
            .into_iter()
            .map(|d| d.rule)
            .collect();
        rules.dedup();
        rules
    }

    #[test]
    fn fixture_missing_safety_is_caught() {
        let hits = rules_hit("bad/missing_safety.rs");
        assert!(hits.contains(&rules::PL001), "{hits:?}");
        // An un-allowlisted file containing unsafe also trips PL002.
        assert!(hits.contains(&rules::PL002), "{hits:?}");
    }

    #[test]
    fn fixture_ungated_protocol_tag_is_caught() {
        let (path, text) = fixture("bad/server/protocol.rs");
        let diags = lint_file(&path, &text, &Allowlist::empty());
        assert!(diags.iter().all(|d| d.rule == rules::PL004), "{diags:?}");
        // Both plants fire: the unregistered tag and the registered-
        // but-ungated one.
        assert!(
            diags.iter().any(|d| d.message.contains("TAG_ROGUE")),
            "{diags:?}"
        );
        assert!(
            diags.iter().any(|d| d.message.contains("TAG_FUTURE")),
            "{diags:?}"
        );
    }

    #[test]
    fn fixture_timing_in_kernel_is_caught() {
        let hits = rules_hit("bad/estimators/batch.rs");
        assert_eq!(hits, vec![rules::PL003], "{hits:?}");
    }

    #[test]
    fn fixture_bare_unwrap_is_caught() {
        let (path, text) = fixture("bad/server/conn.rs");
        let diags = lint_file(&path, &text, &Allowlist::empty());
        assert!(!diags.is_empty());
        assert!(diags.iter().all(|d| d.rule == rules::PL005), "{diags:?}");
        // Three plants: bare unwrap, empty expect, undocumented expect.
        assert_eq!(diags.len(), 3, "{diags:?}");
    }

    #[test]
    fn fixture_duplicate_stat_key_is_caught() {
        let (path, text) = fixture("bad/metrics.rs");
        let diags = lint_file(&path, &text, &Allowlist::empty());
        assert!(diags.iter().all(|d| d.rule == rules::PL006), "{diags:?}");
        assert!(
            diags.iter().any(|d| d.message.contains("duplicate")),
            "{diags:?}"
        );
        assert!(
            diags.iter().any(|d| d.message.contains("snake_case")),
            "{diags:?}"
        );
        assert!(
            diags
                .iter()
                .any(|d| d.message.contains("no Prometheus exposition family")),
            "{diags:?}"
        );
    }

    #[test]
    fn fixture_clean_file_passes() {
        let (path, text) = fixture("clean/widget.rs");
        let diags = lint_file(&path, &text, &Allowlist::empty());
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn fixture_test_blocks_are_exempt() {
        let (path, text) = fixture("clean/server/conn.rs");
        let diags = lint_file(&path, &text, &Allowlist::empty());
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn allowlist_suppresses_only_its_rule_and_file() {
        let allow = Allowlist::parse("# comment\nPL002 rust/lint_fixtures/bad/missing_safety.rs\n");
        let (path, text) = fixture("bad/missing_safety.rs");
        let rules_left: Vec<&str> = lint_file(&path, &text, &allow)
            .into_iter()
            .map(|d| d.rule)
            .collect();
        assert!(rules_left.contains(&rules::PL001), "{rules_left:?}");
        assert!(!rules_left.contains(&rules::PL002), "{rules_left:?}");
    }

    /// The repo's own tree must be lint-clean — the same run CI blocks
    /// on, kept inside `cargo test` so a violation cannot land even
    /// where only the test suite runs.
    #[test]
    fn repo_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run_repo(root).expect("scanning rust/src");
        assert!(report.files > 50, "suspiciously few files scanned");
        let rendered: Vec<String> = report.diags.iter().map(|d| d.to_string()).collect();
        assert!(rendered.is_empty(), "{}", rendered.join("\n"));
    }
}
