//! Row-range shard assignment with rebalancing — used by the *ingest*
//! side to partition turnstile streams across ingest workers, by bulk
//! sketching to split a corpus into projection jobs, and by the
//! multi-node serving layer as the cluster's row → node ownership map
//! (`server::cluster` builds a `ShardSet` from the per-node `ShardMap`
//! frames and routes every query through [`ShardSet::owner`]).
//! [`ReplicaSet`] is the replica-aware form: the same row → shard map
//! served by R nodes per shard, so the cluster can fail over between
//! siblings when one dies.
//!
//! (Query-side load balancing is the router's power-of-two-choices; this
//! module owns the data-partitioning maps.)

/// Smallest per-shard weight [`ShardSet::weighted`] honours: anything
/// at or below it (including 0, negatives, and NaN) clamps here. Small
/// enough that a genuinely cheap shard dominates the split, large
/// enough that `1/w` and the capacity sum stay finite.
pub const MIN_WEIGHT: f64 = 1e-9;

/// Largest per-shard weight [`ShardSet::weighted`] honours: `+inf`
/// (and anything above) clamps here, so a "infinitely slow" shard gets
/// a zero-width range instead of poisoning the capacity sum with
/// `1/inf` / `inf − inf` arithmetic.
pub const MAX_WEIGHT: f64 = 1e12;

/// Clamp one observed cost into `[MIN_WEIGHT, MAX_WEIGHT]`; NaN — an
/// undefined observation — is treated as "no load observed".
fn sanitize_weight(w: f64) -> f64 {
    if w.is_nan() || w < MIN_WEIGHT {
        MIN_WEIGHT
    } else if w > MAX_WEIGHT {
        MAX_WEIGHT
    } else {
        w
    }
}

/// Contiguous row-range shards over n rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardSet {
    /// `bounds[s]..bounds[s+1]` is shard s's row range.
    bounds: Vec<usize>,
}

impl ShardSet {
    /// Evenly split n rows into `shards` ranges (remainder spread over
    /// the first shards).
    pub fn even(n: usize, shards: usize) -> ShardSet {
        assert!(shards > 0);
        let base = n / shards;
        let rem = n % shards;
        let mut bounds = Vec::with_capacity(shards + 1);
        let mut at = 0usize;
        bounds.push(0);
        for s in 0..shards {
            at += base + usize::from(s < rem);
            bounds.push(at);
        }
        ShardSet { bounds }
    }

    /// Split by explicit per-shard load weights (e.g. observed ingest
    /// rates or queue depths): shard s gets a row span proportional to
    /// 1/weight[s].
    ///
    /// Weights are **sanitized, not asserted**: an idle node reports a
    /// cost of exactly 0 (`queue_depth_total = 0`), so zero, negative,
    /// NaN, and sub-epsilon weights clamp to [`MIN_WEIGHT`] ("as cheap
    /// as expressible" — the shard gets the most rows), and infinite
    /// or huge weights clamp to [`MAX_WEIGHT`] ("as expensive as
    /// expressible" — the shard gets the fewest). Stats-driven
    /// rebalancing can therefore feed raw observed costs straight in
    /// without a panic path.
    pub fn weighted(n: usize, weights: &[f64]) -> ShardSet {
        assert!(!weights.is_empty());
        // Capacity ∝ 1/weight (a slow shard gets fewer rows).
        let caps: Vec<f64> = weights.iter().map(|&w| 1.0 / sanitize_weight(w)).collect();
        let total: f64 = caps.iter().sum();
        let mut bounds = Vec::with_capacity(weights.len() + 1);
        bounds.push(0usize);
        let mut acc = 0.0;
        for (s, c) in caps.iter().enumerate() {
            acc += c;
            let b = if s + 1 == weights.len() {
                n
            } else {
                ((acc / total) * n as f64).round() as usize
            };
            bounds.push(b.max(*bounds.last().unwrap()));
        }
        ShardSet { bounds }
    }

    /// Reconstruct from explicit bounds (`bounds[s]..bounds[s+1]` is
    /// shard s's range) — how the cluster client rebuilds the row map
    /// from per-node `ShardMap` frames. Rejects anything that is not a
    /// partition: fewer than two entries, a nonzero origin, or a
    /// decreasing bound.
    pub fn from_bounds(bounds: Vec<usize>) -> Option<ShardSet> {
        if bounds.len() < 2 || bounds[0] != 0 || bounds.windows(2).any(|w| w[0] > w[1]) {
            return None;
        }
        Some(ShardSet { bounds })
    }

    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total rows covered (the exclusive upper bound of the last shard).
    pub fn rows(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Which shard owns row i.
    ///
    /// `bounds` may contain duplicates: `weighted` under extreme skew
    /// produces zero-width shards, and `binary_search` over duplicates
    /// returns *any* matching index — which can be an empty shard whose
    /// range does not contain the row. `partition_point` instead finds
    /// the first bound strictly greater than `row`; the shard just
    /// before it is the unique non-empty owner.
    pub fn owner(&self, row: usize) -> usize {
        assert!(row < self.rows(), "row {row} out of range");
        self.bounds.partition_point(|&b| b <= row) - 1
    }

    pub fn range(&self, shard: usize) -> std::ops::Range<usize> {
        self.bounds[shard]..self.bounds[shard + 1]
    }

    /// Rebalance: recompute ranges from observed per-shard costs while
    /// keeping total coverage; returns the rows that changed owner as
    /// (row_start, row_end, from, to) move descriptors.
    pub fn rebalance(&self, costs: &[f64]) -> (ShardSet, Vec<(usize, usize, usize, usize)>) {
        assert_eq!(costs.len(), self.shards());
        let n = self.rows();
        let new = ShardSet::weighted(n, costs);
        let mut moves = Vec::new();
        // Compute ownership diffs as maximal runs.
        let mut row = 0usize;
        while row < n {
            let from = self.owner(row);
            let to = new.owner(row);
            let mut end = row + 1;
            while end < n && self.owner(end) == from && new.owner(end) == to {
                end += 1;
            }
            if from != to {
                moves.push((row, end, from, to));
            }
            row = end;
        }
        (new, moves)
    }
}

/// One rebalance move for one replica: rows `start..end` change owner
/// from `(from, replica)` to `(to, replica)` — the per-replica form of
/// [`ShardSet::rebalance`]'s `(start, end, from, to)` descriptors,
/// which is what an `AdoptShard` sweep over a replicated cluster
/// executes (every replica of a range moves in lockstep, each under
/// its own node).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaMove {
    pub start: usize,
    pub end: usize,
    pub from: usize,
    pub to: usize,
    pub replica: usize,
}

/// Replica-aware placement: a [`ShardSet`] row → shard map served by
/// `replicas` nodes per shard, so every row is covered by exactly
/// `replicas` distinct nodes. Nodes are addressed as
/// `(shard, replica)` pairs with a flat shard-major [`Self::slot`]
/// order — the order the cluster client keeps its connections and
/// per-node metrics in.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaSet {
    map: ShardSet,
    replicas: usize,
}

impl ReplicaSet {
    pub fn new(map: ShardSet, replicas: usize) -> ReplicaSet {
        assert!(replicas > 0);
        ReplicaSet { map, replicas }
    }

    /// The underlying row → shard map (shared by every replica).
    pub fn map(&self) -> &ShardSet {
        &self.map
    }

    pub fn shards(&self) -> usize {
        self.map.shards()
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Total nodes in the placement (`shards × replicas`).
    pub fn nodes(&self) -> usize {
        self.map.shards() * self.replicas
    }

    pub fn rows(&self) -> usize {
        self.map.rows()
    }

    /// Flat node slot of `(shard, replica)` — shard-major, so a
    /// shard's replica group is contiguous.
    pub fn slot(&self, shard: usize, replica: usize) -> usize {
        assert!(shard < self.shards() && replica < self.replicas);
        shard * self.replicas + replica
    }

    /// The `replicas` distinct nodes serving `row`, as
    /// `(shard, replica)` pairs in replica order.
    pub fn owners(&self, row: usize) -> Vec<(usize, usize)> {
        let shard = self.map.owner(row);
        (0..self.replicas).map(|r| (shard, r)).collect()
    }

    /// Rebalance the shared row map by per-shard costs; the returned
    /// moves are the per-replica ownership diff — exactly
    /// [`ShardSet::rebalance`]'s moves, once per replica index, so an
    /// `AdoptShard` sweep has one descriptor per node that must move.
    pub fn rebalance(&self, costs: &[f64]) -> (ReplicaSet, Vec<ReplicaMove>) {
        let (new_map, shard_moves) = self.map.rebalance(costs);
        let mut moves = Vec::with_capacity(shard_moves.len() * self.replicas);
        for &(start, end, from, to) in &shard_moves {
            for replica in 0..self.replicas {
                moves.push(ReplicaMove {
                    start,
                    end,
                    from,
                    to,
                    replica,
                });
            }
        }
        (ReplicaSet::new(new_map, self.replicas), moves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_covers_everything() {
        let s = ShardSet::even(103, 4);
        assert_eq!(s.shards(), 4);
        let mut total = 0;
        for i in 0..4 {
            total += s.range(i).len();
        }
        assert_eq!(total, 103);
        // ranges differ by at most 1
        let lens: Vec<usize> = (0..4).map(|i| s.range(i).len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }

    #[test]
    fn owner_is_consistent_with_ranges() {
        let s = ShardSet::even(50, 3);
        for shard in 0..3 {
            for row in s.range(shard) {
                assert_eq!(s.owner(row), shard, "row {row}");
            }
        }
    }

    #[test]
    fn weighted_gives_slow_shards_fewer_rows() {
        // shard 1 is 4x slower => should own ~4x fewer rows
        let s = ShardSet::weighted(100, &[1.0, 4.0]);
        let fast = s.range(0).len();
        let slow = s.range(1).len();
        assert!(fast > 3 * slow, "fast {fast} slow {slow}");
        assert_eq!(fast + slow, 100);
    }

    /// Regression for the duplicate-bounds ownership bug: `weighted`
    /// under extreme skew produces zero-width shards (duplicate
    /// bounds), and the old `binary_search`-based `owner` could return
    /// an *empty* shard whose range does not contain the row.
    #[test]
    fn owner_contains_row_under_extreme_weights() {
        let s = ShardSet::weighted(10, &[1.0, 1000.0, 1.0]);
        assert!(
            (0..s.shards()).any(|i| s.range(i).is_empty()),
            "expected a zero-width shard under 1000x skew"
        );
        for row in 0..10 {
            let o = s.owner(row);
            assert!(s.range(o).contains(&row), "row {row} -> shard {o} ({:?})", s.range(o));
        }
    }

    /// Property test over skewed weighted splits (and over-sharded even
    /// splits): for every row, the owning shard's range contains it,
    /// and the ranges partition the row space.
    #[test]
    fn owner_is_inverse_of_range_for_all_rows_property() {
        use crate::numerics::{Rng, Xoshiro256pp};
        let mut cases: Vec<(usize, Vec<f64>)> = vec![
            (10, vec![1.0, 1000.0, 1.0]),
            (10, vec![1000.0, 1.0, 1000.0, 1.0]),
            (1, vec![5.0, 5.0, 5.0]),
            (103, vec![1.0, 1e6, 1e6, 1.0, 1e6]),
            (7, vec![1e9, 1.0]),
            (3, vec![1.0; 8]), // more shards than rows
        ];
        let mut rng = Xoshiro256pp::new(0x5AAD);
        for _ in 0..200 {
            let n = rng.below(120) as usize + 1;
            let shards = rng.below(8) as usize + 1;
            let weights: Vec<f64> = (0..shards)
                .map(|_| 10f64.powf(rng.uniform() * 12.0 - 6.0))
                .collect();
            cases.push((n, weights));
        }
        for (n, weights) in cases {
            for s in [ShardSet::weighted(n, &weights), ShardSet::even(n, weights.len())] {
                let covered: usize = (0..s.shards()).map(|i| s.range(i).len()).sum();
                assert_eq!(covered, n, "n={n} weights={weights:?}");
                for row in 0..n {
                    let o = s.owner(row);
                    assert!(
                        s.range(o).contains(&row),
                        "row {row} -> shard {o} range {:?} (n={n} weights={weights:?})",
                        s.range(o)
                    );
                }
            }
        }
    }

    /// Regression for the zero-cost rebalance panic: `weighted` used
    /// to assert `w > 0.0`, so `ClusterClient::rebalance` panicked on
    /// the most common stats-driven input — an idle node reporting
    /// `queue_depth_total = 0`. Zero, NaN, and infinite costs must now
    /// clamp, keep full coverage, and keep `owner`/`range` consistent.
    #[test]
    fn weighted_and_rebalance_accept_zero_nan_and_infinite_costs() {
        let hostile: Vec<(usize, Vec<f64>)> = vec![
            (100, vec![0.0, 1.0, 1.0]),              // idle node
            (100, vec![0.0, 0.0, 0.0]),              // wholly idle cluster
            (100, vec![f64::NAN, 1.0]),              // undefined observation
            (100, vec![f64::INFINITY, 1.0]),         // wedged node
            (100, vec![f64::INFINITY, f64::INFINITY]),
            (100, vec![-3.0, 1.0]),                  // garbage negative
            (7, vec![0.0, f64::NAN, f64::INFINITY, 1.0]),
            (1, vec![0.0, 0.0]),
        ];
        for (n, costs) in hostile {
            let s = ShardSet::weighted(n, &costs);
            assert_eq!(s.shards(), costs.len(), "costs {costs:?}");
            let covered: usize = (0..s.shards()).map(|i| s.range(i).len()).sum();
            assert_eq!(covered, n, "coverage lost under costs {costs:?}");
            for row in 0..n {
                let o = s.owner(row);
                assert!(s.range(o).contains(&row), "row {row} costs {costs:?}");
            }
            // rebalance (which feeds weighted) must not panic either,
            // and its moves must stay the exact ownership diff.
            let start = ShardSet::even(n, costs.len());
            let (new, moves) = start.rebalance(&costs);
            assert_eq!(new.rows(), n);
            for &(ms, me, from, to) in &moves {
                assert!(ms < me && me <= n);
                for row in ms..me {
                    assert_eq!(start.owner(row), from);
                    assert_eq!(new.owner(row), to);
                }
            }
        }
        // The semantics, not just the absence of a panic: an idle
        // (zero-cost) shard absorbs rows from a loaded one, and an
        // infinitely slow shard sheds everything it can.
        let s = ShardSet::weighted(100, &[0.0, 1.0]);
        assert!(s.range(0).len() > 95, "idle shard must absorb rows: {:?}", s.range(0));
        let s = ShardSet::weighted(100, &[f64::INFINITY, 1.0]);
        assert!(s.range(0).len() < 5, "wedged shard must shed rows: {:?}", s.range(0));
    }

    /// Property: a replica placement covers every row exactly R times,
    /// on R distinct nodes, and its rebalance moves are exactly the
    /// per-replica ownership diff (the [`ShardSet`] diff repeated once
    /// per replica index, nothing more, nothing less).
    #[test]
    fn replica_placement_covers_every_row_r_times_and_moves_are_the_diff_property() {
        use crate::numerics::{Rng, Xoshiro256pp};
        let mut rng = Xoshiro256pp::new(0x9E91);
        let mut cases: Vec<(usize, usize, usize, Vec<f64>)> = vec![
            (40, 3, 2, vec![1.0, 3.0, 1.0]),
            (10, 1, 4, vec![2.0]),
            (64, 4, 1, vec![1.0, 0.0, f64::INFINITY, 1.0]),
        ];
        for _ in 0..100 {
            let n = rng.below(120) as usize + 1;
            let shards = rng.below(5) as usize + 1;
            let replicas = rng.below(4) as usize + 1;
            let costs: Vec<f64> = (0..shards)
                .map(|_| 10f64.powf(rng.uniform() * 8.0 - 4.0))
                .collect();
            cases.push((n, shards, replicas, costs));
        }
        for (n, shards, replicas, costs) in cases {
            let placement = ReplicaSet::new(ShardSet::even(n, shards), replicas);
            assert_eq!(placement.nodes(), shards * replicas);
            // Coverage: every row on exactly R distinct node slots,
            // and total coverage over all rows is n × R.
            let mut covered = vec![0usize; placement.nodes()];
            for row in 0..n {
                let owners = placement.owners(row);
                assert_eq!(owners.len(), replicas, "row {row} covered {} times", owners.len());
                let mut slots: Vec<usize> =
                    owners.iter().map(|&(s, r)| placement.slot(s, r)).collect();
                slots.sort_unstable();
                slots.dedup();
                assert_eq!(slots.len(), replicas, "row {row} replicas not distinct");
                for slot in slots {
                    covered[slot] += 1;
                }
                // Every replica of a row serves the same range.
                for &(s, _) in &owners {
                    assert!(placement.map().range(s).contains(&row));
                }
            }
            assert_eq!(covered.iter().sum::<usize>(), n * replicas);
            // Moves are exactly the per-replica diff of the shard map.
            let (new, moves) = placement.rebalance(&costs);
            assert_eq!(new.replicas(), replicas);
            let (expect_map, shard_moves) = placement.map().rebalance(&costs);
            assert_eq!(new.map(), &expect_map, "replica rebalance shares the shard map");
            assert_eq!(moves.len(), shard_moves.len() * replicas);
            for replica in 0..replicas {
                let per_replica: Vec<(usize, usize, usize, usize)> = moves
                    .iter()
                    .filter(|m| m.replica == replica)
                    .map(|m| (m.start, m.end, m.from, m.to))
                    .collect();
                assert_eq!(
                    per_replica, shard_moves,
                    "replica {replica} moves must be the shard diff (n={n} costs={costs:?})"
                );
            }
        }
    }

    #[test]
    fn from_bounds_validates_partitions() {
        let s = ShardSet::from_bounds(vec![0, 5, 5, 10]).expect("valid bounds");
        assert_eq!(s.shards(), 3);
        assert_eq!(s.rows(), 10);
        assert_eq!(s.owner(5), 2, "duplicate bound resolves to the non-empty shard");
        assert!(ShardSet::from_bounds(vec![]).is_none());
        assert!(ShardSet::from_bounds(vec![0]).is_none());
        assert!(ShardSet::from_bounds(vec![1, 5]).is_none(), "nonzero origin");
        assert!(ShardSet::from_bounds(vec![0, 5, 3]).is_none(), "decreasing");
    }

    #[test]
    fn rebalance_produces_moves_and_coverage() {
        let s = ShardSet::even(100, 2);
        let (new, moves) = s.rebalance(&[1.0, 3.0]); // shard 1 got slow
        assert_eq!(new.range(0).len() + new.range(1).len(), 100);
        assert!(!moves.is_empty());
        // all moved rows now belong to their 'to' shard
        for &(start, end, _from, to) in &moves {
            for row in start..end {
                assert_eq!(new.owner(row), to);
            }
        }
        // balanced costs => no moves
        let (_, no_moves) = s.rebalance(&[1.0, 1.0]);
        assert!(no_moves.is_empty());
    }

    /// Property: over random starting maps and random cost vectors,
    /// the returned moves are *exactly* the ownership diff — applying
    /// them to the old map reproduces the new map row for row, and
    /// every row not covered by a move keeps its old owner. (The live
    /// membership machinery hands these descriptors to `AdoptShard`
    /// sweeps, so "exact diff" is a correctness contract, not a nice-
    /// to-have.)
    #[test]
    fn rebalance_moves_are_exactly_the_ownership_diff_property() {
        use crate::numerics::{Rng, Xoshiro256pp};
        let mut rng = Xoshiro256pp::new(0xB417);
        let mut cases: Vec<(ShardSet, Vec<f64>)> = vec![
            (ShardSet::even(100, 2), vec![1.0, 3.0]),
            (ShardSet::even(1, 4), vec![1.0, 2.0, 3.0, 4.0]),
            (ShardSet::weighted(64, &[1.0, 1000.0, 1.0]), vec![1.0, 1.0, 1.0]),
        ];
        for _ in 0..200 {
            let n = rng.below(150) as usize + 1;
            let shards = rng.below(6) as usize + 1;
            let start_weights: Vec<f64> = (0..shards)
                .map(|_| 10f64.powf(rng.uniform() * 8.0 - 4.0))
                .collect();
            let costs: Vec<f64> = (0..shards)
                .map(|_| 10f64.powf(rng.uniform() * 8.0 - 4.0))
                .collect();
            cases.push((ShardSet::weighted(n, &start_weights), costs));
        }
        for (old, costs) in cases {
            let n = old.rows();
            let (new, moves) = old.rebalance(&costs);
            assert_eq!(new.rows(), n, "rebalance must keep total coverage");
            assert_eq!(new.shards(), old.shards(), "rebalance must keep the shard count");
            // Moves are well-formed: nonempty, in-range, sorted,
            // non-overlapping runs whose endpoints really are the old
            // and new owners — and never a no-op.
            let mut prev_end = 0usize;
            for &(start, end, from, to) in &moves {
                assert!(start < end && end <= n, "degenerate move {start}..{end}");
                assert!(start >= prev_end, "moves overlap or are unsorted");
                assert_ne!(from, to, "a move must change the owner");
                prev_end = end;
                for row in start..end {
                    assert_eq!(old.owner(row), from, "move 'from' mismatch at {row}");
                    assert_eq!(new.owner(row), to, "move 'to' mismatch at {row}");
                }
            }
            // Applying the moves to the old map reproduces the new map
            // exactly; rows outside every move keep their old owner.
            for row in 0..n {
                let moved_to = moves
                    .iter()
                    .find(|&&(s, e, _, _)| (s..e).contains(&row))
                    .map(|&(_, _, _, to)| to);
                let expect = moved_to.unwrap_or_else(|| old.owner(row));
                assert_eq!(
                    new.owner(row),
                    expect,
                    "row {row}: applying moves to the old map must reproduce the new map \
                     (costs {costs:?})"
                );
            }
        }
    }
}
