//! Row-range shard assignment with rebalancing — used by the *ingest*
//! side to partition turnstile streams across ingest workers, and by
//! bulk sketching to split a corpus into projection jobs.
//!
//! (Query-side load balancing is the router's power-of-two-choices; this
//! module owns the data-partitioning maps.)

/// Contiguous row-range shards over n rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardSet {
    /// `bounds[s]..bounds[s+1]` is shard s's row range.
    bounds: Vec<usize>,
}

impl ShardSet {
    /// Evenly split n rows into `shards` ranges (remainder spread over
    /// the first shards).
    pub fn even(n: usize, shards: usize) -> ShardSet {
        assert!(shards > 0);
        let base = n / shards;
        let rem = n % shards;
        let mut bounds = Vec::with_capacity(shards + 1);
        let mut at = 0usize;
        bounds.push(0);
        for s in 0..shards {
            at += base + usize::from(s < rem);
            bounds.push(at);
        }
        ShardSet { bounds }
    }

    /// Split by explicit per-shard load weights (e.g. observed ingest
    /// rates): shard s gets a row span proportional to 1/weight[s].
    pub fn weighted(n: usize, weights: &[f64]) -> ShardSet {
        assert!(!weights.is_empty());
        assert!(weights.iter().all(|&w| w > 0.0));
        // Capacity ∝ 1/weight (a slow shard gets fewer rows).
        let caps: Vec<f64> = weights.iter().map(|w| 1.0 / w).collect();
        let total: f64 = caps.iter().sum();
        let mut bounds = Vec::with_capacity(weights.len() + 1);
        bounds.push(0usize);
        let mut acc = 0.0;
        for (s, c) in caps.iter().enumerate() {
            acc += c;
            let b = if s + 1 == weights.len() {
                n
            } else {
                ((acc / total) * n as f64).round() as usize
            };
            bounds.push(b.max(*bounds.last().unwrap()));
        }
        ShardSet { bounds }
    }

    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Which shard owns row i.
    pub fn owner(&self, row: usize) -> usize {
        assert!(row < *self.bounds.last().unwrap(), "row {row} out of range");
        // binary search over bounds
        match self.bounds.binary_search(&row) {
            Ok(exact) => exact.min(self.shards() - 1),
            Err(ins) => ins - 1,
        }
    }

    pub fn range(&self, shard: usize) -> std::ops::Range<usize> {
        self.bounds[shard]..self.bounds[shard + 1]
    }

    /// Rebalance: recompute ranges from observed per-shard costs while
    /// keeping total coverage; returns the rows that changed owner as
    /// (row_start, row_end, from, to) move descriptors.
    pub fn rebalance(&self, costs: &[f64]) -> (ShardSet, Vec<(usize, usize, usize, usize)>) {
        assert_eq!(costs.len(), self.shards());
        let n = *self.bounds.last().unwrap();
        let new = ShardSet::weighted(n, costs);
        let mut moves = Vec::new();
        for row_block in 0..self.shards().max(new.shards()) {
            let _ = row_block;
        }
        // Compute ownership diffs as maximal runs.
        let mut row = 0usize;
        while row < n {
            let from = self.owner(row);
            let to = new.owner(row);
            let mut end = row + 1;
            while end < n && self.owner(end) == from && new.owner(end) == to {
                end += 1;
            }
            if from != to {
                moves.push((row, end, from, to));
            }
            row = end;
        }
        (new, moves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_covers_everything() {
        let s = ShardSet::even(103, 4);
        assert_eq!(s.shards(), 4);
        let mut total = 0;
        for i in 0..4 {
            total += s.range(i).len();
        }
        assert_eq!(total, 103);
        // ranges differ by at most 1
        let lens: Vec<usize> = (0..4).map(|i| s.range(i).len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }

    #[test]
    fn owner_is_consistent_with_ranges() {
        let s = ShardSet::even(50, 3);
        for shard in 0..3 {
            for row in s.range(shard) {
                assert_eq!(s.owner(row), shard, "row {row}");
            }
        }
    }

    #[test]
    fn weighted_gives_slow_shards_fewer_rows() {
        // shard 1 is 4x slower => should own ~4x fewer rows
        let s = ShardSet::weighted(100, &[1.0, 4.0]);
        let fast = s.range(0).len();
        let slow = s.range(1).len();
        assert!(fast > 3 * slow, "fast {fast} slow {slow}");
        assert_eq!(fast + slow, 100);
    }

    #[test]
    fn rebalance_produces_moves_and_coverage() {
        let s = ShardSet::even(100, 2);
        let (new, moves) = s.rebalance(&[1.0, 3.0]); // shard 1 got slow
        assert_eq!(new.range(0).len() + new.range(1).len(), 100);
        assert!(!moves.is_empty());
        // all moved rows now belong to their 'to' shard
        for &(start, end, _from, to) in &moves {
            for row in start..end {
                assert_eq!(new.owner(row), to);
            }
        }
        // balanced costs => no moves
        let (_, no_moves) = s.rebalance(&[1.0, 1.0]);
        assert!(no_moves.is_empty());
    }
}
