//! Shard worker loop: form a batch, snapshot the store once, execute
//! every query in the batch through the fused abs-diff-select kernel
//! with one reused scratch — no per-query copies or allocations on the
//! estimate path.

use super::backpressure::BoundedQueue;
use super::batcher::{BatchPolicy, Batcher};
use super::{Job, Query, Reply, Shared, TraceSpans};
use crate::estimators::{BatchScratch, FusedDiffEstimator};
use crate::sketch::{SketchDtype, SketchStore};
use std::sync::Arc;
use std::time::Instant;

pub(crate) fn run(shared: Arc<Shared>, queue: Arc<BoundedQueue<Job>>, policy: BatchPolicy) {
    let batcher = Batcher::new(policy);
    let mut batch: Vec<Job> = Vec::with_capacity(policy.max_batch);
    let mut scratch = BatchScratch::default();
    loop {
        batcher.next_batch(&queue, &mut batch);
        if batch.is_empty() {
            return; // queue closed & drained
        }
        let t_batch = Instant::now();
        // One snapshot per batch: queries in a batch see a consistent
        // epoch, and the Arc clone cost is amortized. Ownership is
        // snapshotted the same way — an `adopt_shard` swap lands
        // *between* batches, never inside one.
        let store = shared.snapshot();
        let ownership = shared.ownership.lock().unwrap().clone();
        shared.metrics.batches_formed.inc();
        shared.metrics.batch_fill.add(batch.len() as u64);
        for job in batch.drain(..) {
            let kind = job.query.kind();
            // Queries stamped with the previous epoch (admitted just
            // before an adoption) still scan the range they were
            // routed under. A stamp that no longer resolves (two
            // adoptions inside one queue residence) is refused — never
            // silently answered under a range the client did not route
            // with.
            let Some(owned) = ownership.range_for(job.epoch) else {
                shared.metrics.queries_completed.inc();
                job.reply.send(
                    job.seq,
                    Reply::WrongEpoch {
                        current: ownership.epoch,
                    },
                    job.trace,
                );
                continue;
            };
            let t_est = Instant::now();
            let (reply, estimates) = execute(&shared, &store, &job.query, &owned, &mut scratch);
            // One clock read per query; the histogram tracks cost *per
            // fused estimate* so TopK/Block scans land in the same
            // units as single pairs (see metrics::PipelineMetrics).
            let spent = t_est.elapsed();
            let est_ns = spent.as_nanos() as u64 / estimates.max(1);
            shared.metrics.estimate_latency[kind.index()].record_ns(est_ns);
            // Whole-scan latency per kind, plus the live rows/s gauge —
            // this is where the multi-threaded scan win is observable
            // from a running cluster (Stats frame / loadgen report).
            match &job.query {
                Query::TopK { .. } => {
                    shared.metrics.scan_latency[kind.index()].record(spent);
                    let ns = (spent.as_nanos() as u64).max(1);
                    let rps = (estimates as u128 * 1_000_000_000 / ns as u128)
                        .min(i64::MAX as u128) as i64;
                    shared.metrics.scan_rows_per_s.set(rps);
                }
                Query::Block { .. } => {
                    shared.metrics.scan_latency[kind.index()].record(spent);
                }
                Query::Pair { .. } => {}
            }
            shared
                .metrics
                .query_latency
                .record(job.submitted.elapsed());
            shared.metrics.queries_completed.inc();
            // Fill the trace's queue/scan stages from timings already
            // taken for the metrics above — tracing adds no clock reads
            // to this loop. Traced jobs clamp to >= 1ns so every stage
            // of a completed trace is visibly non-zero.
            let mut spans = job.trace;
            let queue_ns = (t_est - job.submitted).as_nanos() as u64;
            let scan_ns = spent.as_nanos() as u64;
            if spans.trace_id != 0 {
                spans.queue_ns = queue_ns.max(1);
                spans.scan_ns = scan_ns.max(1);
            } else {
                spans = TraceSpans {
                    queue_ns,
                    scan_ns,
                    ..spans
                };
            }
            // Receiver may have given up (client dropped) — ignore.
            job.reply.send(job.seq, reply, spans);
        }
        shared.metrics.batch_latency.record(t_batch.elapsed());
    }
}

/// Execute one (validated) query against a snapshot, returning the
/// reply plus how many fused estimates it cost (for the per-estimate
/// latency accounting). Self-pairs are exactly zero for every kind;
/// TopK excludes the anchor row itself.
fn execute(
    shared: &Shared,
    store: &SketchStore,
    query: &Query,
    owned: &std::ops::Range<usize>,
    scratch: &mut BatchScratch,
) -> (Reply, u64) {
    // Representation dispatch: a sign-bits snapshot routes to the
    // XOR+popcount scans; admission guarantees the kind matches the
    // dtype, so the dense arm below never sees a Sign query.
    if store.dtype() == SketchDtype::SignBits {
        return execute_sign(shared, store, query, owned);
    }
    let est = shared.fused(query.kind());
    match query {
        Query::Pair { i, j, .. } => {
            let (i, j) = (*i as usize, *j as usize);
            let d = if i == j {
                0.0
            } else {
                est.estimate_diff(store.row(i), store.row(j), scratch)
            };
            (Reply::Pair(d), 1)
        }
        Query::TopK { i, m, .. } => {
            let i = *i as usize;
            // Candidates are the *owned* row range (the whole store on
            // an unsharded node): a sharded node contributes the
            // partial top-m over its slice, and the cluster client
            // merges partials by (distance, row) — the same order the
            // scan produces — so the merged result is bit-identical to
            // a single node scanning everything. The scan itself (the
            // streaming bounded insertion, optionally fanned out over
            // `scan_threads` sub-ranges) lives on `SketchStore` so the
            // embedded and serving paths share one implementation.
            let (best, scanned) =
                store.top_m_scan(est, i, owned.clone(), *m, shared.scan_threads, scratch);
            shared.metrics.topk_candidates_scanned.add(scanned);
            (Reply::TopK(best), scanned)
        }
        Query::Block { rows, cols, .. } => {
            let mut out = Vec::new();
            store.estimate_block_par(est, rows, cols, shared.scan_threads, scratch, &mut out);
            let cells = out.len() as u64;
            (Reply::Block(out), cells)
        }
    }
}

/// The sign-bits arm of [`execute`]: identical plan shapes and reply
/// ordering, but each distance is a normalized Hamming mismatch over
/// bit-packed rows (no estimator object, no f32 scratch). Sharded TopK
/// partials merge under the same `(distance, row)` order as the dense
/// scan, so cluster merges stay bit-identical to a single node's.
fn execute_sign(
    shared: &Shared,
    store: &SketchStore,
    query: &Query,
    owned: &std::ops::Range<usize>,
) -> (Reply, u64) {
    match query {
        Query::Pair { i, j, .. } => {
            let d = store.estimate_pair_sign(*i as usize, *j as usize);
            (Reply::Pair(d), 1)
        }
        Query::TopK { i, m, .. } => {
            let (best, scanned) =
                store.top_m_scan_sign(*i as usize, owned.clone(), *m, shared.scan_threads);
            shared.metrics.topk_candidates_scanned.add(scanned);
            (Reply::TopK(best), scanned)
        }
        Query::Block { rows, cols, .. } => {
            let mut out = Vec::new();
            store.estimate_block_sign_par(rows, cols, shared.scan_threads, &mut out);
            let cells = out.len() as u64;
            (Reply::Block(out), cells)
        }
    }
}
