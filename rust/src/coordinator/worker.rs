//! Shard worker loop: form a batch, snapshot the store once, serve every
//! query in the batch with a reused scratch buffer.

use super::backpressure::BoundedQueue;
use super::batcher::{BatchPolicy, Batcher};
use super::{Job, Shared};
use std::sync::Arc;
use std::time::Instant;

pub(crate) fn run(shared: Arc<Shared>, queue: Arc<BoundedQueue<Job>>, policy: BatchPolicy) {
    let batcher = Batcher::new(policy);
    let mut batch: Vec<Job> = Vec::with_capacity(policy.max_batch);
    let mut buf: Vec<f64> = Vec::new();
    loop {
        batcher.next_batch(&queue, &mut batch);
        if batch.is_empty() {
            return; // queue closed & drained
        }
        let t_batch = Instant::now();
        // One snapshot per batch: queries in a batch see a consistent
        // epoch, and the Arc clone cost is amortized.
        let store = shared.snapshot();
        buf.resize(store.k, 0.0);
        shared.metrics.batches_formed.inc();
        shared.metrics.batch_fill.add(batch.len() as u64);
        for job in batch.drain(..) {
            let (i, j) = (job.query.i as usize, job.query.j as usize);
            let d = if i == j {
                0.0
            } else {
                store.diff_into(i, j, &mut buf);
                shared.estimate(job.query.kind, &mut buf)
            };
            shared
                .metrics
                .query_latency
                .record(job.submitted.elapsed());
            shared.metrics.queries_completed.inc();
            // Receiver may have given up (client dropped) — ignore.
            let _ = job.reply.send((job.seq, d));
        }
        shared.metrics.batch_latency.record(t_batch.elapsed());
    }
}
