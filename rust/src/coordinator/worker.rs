//! Shard worker loop: form a batch, snapshot the store once, execute
//! every query in the batch through the fused abs-diff-select kernel
//! with one reused scratch — no per-query copies or allocations on the
//! estimate path.

use super::backpressure::BoundedQueue;
use super::batcher::{BatchPolicy, Batcher};
use super::{Job, Query, Reply, Shared};
use crate::estimators::{BatchScratch, FusedDiffEstimator};
use crate::sketch::SketchStore;
use std::sync::Arc;
use std::time::Instant;

pub(crate) fn run(shared: Arc<Shared>, queue: Arc<BoundedQueue<Job>>, policy: BatchPolicy) {
    let batcher = Batcher::new(policy);
    let mut batch: Vec<Job> = Vec::with_capacity(policy.max_batch);
    let mut scratch = BatchScratch::default();
    loop {
        batcher.next_batch(&queue, &mut batch);
        if batch.is_empty() {
            return; // queue closed & drained
        }
        let t_batch = Instant::now();
        // One snapshot per batch: queries in a batch see a consistent
        // epoch, and the Arc clone cost is amortized. Ownership is
        // snapshotted the same way — an `adopt_shard` swap lands
        // *between* batches, never inside one.
        let store = shared.snapshot();
        let ownership = shared.ownership.lock().unwrap().clone();
        shared.metrics.batches_formed.inc();
        shared.metrics.batch_fill.add(batch.len() as u64);
        for job in batch.drain(..) {
            let kind = job.query.kind();
            // Queries stamped with the previous epoch (admitted just
            // before an adoption) still scan the range they were
            // routed under. A stamp that no longer resolves (two
            // adoptions inside one queue residence) is refused — never
            // silently answered under a range the client did not route
            // with.
            let Some(owned) = ownership.range_for(job.epoch) else {
                shared.metrics.queries_completed.inc();
                let _ = job.reply.send((
                    job.seq,
                    Reply::WrongEpoch {
                        current: ownership.epoch,
                    },
                ));
                continue;
            };
            let t_est = Instant::now();
            let (reply, estimates) = execute(&shared, &store, &job.query, &owned, &mut scratch);
            // One clock read per query; the histogram tracks cost *per
            // fused estimate* so TopK/Block scans land in the same
            // units as single pairs (see metrics::PipelineMetrics).
            let est_ns = t_est.elapsed().as_nanos() as u64 / estimates.max(1);
            shared.metrics.estimate_latency[kind.index()].record_ns(est_ns);
            shared
                .metrics
                .query_latency
                .record(job.submitted.elapsed());
            shared.metrics.queries_completed.inc();
            // Receiver may have given up (client dropped) — ignore.
            let _ = job.reply.send((job.seq, reply));
        }
        shared.metrics.batch_latency.record(t_batch.elapsed());
    }
}

/// Execute one (validated) query against a snapshot, returning the
/// reply plus how many fused estimates it cost (for the per-estimate
/// latency accounting). Self-pairs are exactly zero for every kind;
/// TopK excludes the anchor row itself.
fn execute(
    shared: &Shared,
    store: &SketchStore,
    query: &Query,
    owned: &std::ops::Range<usize>,
    scratch: &mut BatchScratch,
) -> (Reply, u64) {
    let est = shared.fused(query.kind());
    match query {
        Query::Pair { i, j, .. } => {
            let (i, j) = (*i as usize, *j as usize);
            let d = if i == j {
                0.0
            } else {
                est.estimate_diff(store.row(i), store.row(j), scratch)
            };
            (Reply::Pair(d), 1)
        }
        Query::TopK { i, m, .. } => {
            let i = *i as usize;
            // Candidates are the *owned* row range (the whole store on
            // an unsharded node): a sharded node contributes the
            // partial top-m over its slice, and the cluster client
            // merges partials by (distance, row) — the same order this
            // scan produces — so the merged result is bit-identical to
            // a single node scanning everything.
            let lo = owned.start.min(store.n);
            let hi = owned.end.min(store.n);
            let candidates = (hi - lo).saturating_sub(usize::from(lo <= i && i < hi));
            let m = (*m).min(candidates);
            let anchor = store.row(i);
            // Bounded sorted buffer (ascending): insertion beats a heap
            // for the small m of kNN serving, and the reply comes out
            // already ordered. (The materializing variant of this scan
            // lives in `SketchStore::estimate_row_vs_many`; the serving
            // path streams instead so it never holds n distances.)
            let mut best: Vec<(u32, f64)> = Vec::with_capacity(m + 1);
            let mut scanned = 0u64;
            for j in lo..hi {
                if j == i {
                    continue;
                }
                let d = est.estimate_diff(anchor, store.row(j), scratch);
                scanned += 1;
                let worst = best.last().map_or(f64::INFINITY, |&(_, w)| w);
                if best.len() < m || d < worst {
                    let pos = best.partition_point(|&(_, w)| w <= d);
                    best.insert(pos, (j as u32, d));
                    if best.len() > m {
                        best.pop();
                    }
                }
            }
            shared.metrics.topk_candidates_scanned.add(scanned);
            (Reply::TopK(best), scanned)
        }
        Query::Block { rows, cols, .. } => {
            let mut out = Vec::new();
            store.estimate_block(
                est,
                rows.iter().map(|&r| r as usize),
                cols.iter().map(|&c| c as usize),
                scratch,
                &mut out,
            );
            let cells = out.len() as u64;
            (Reply::Block(out), cells)
        }
    }
}
