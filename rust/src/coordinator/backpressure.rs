//! Bounded MPSC queue with explicit rejection — the pipeline's
//! backpressure primitive.
//!
//! `std::sync::mpsc::sync_channel` blocks on full; a serving pipeline
//! must instead *reject* so the caller can shed load or retry with
//! jitter. This wraps a Mutex<VecDeque> + Condvar with a hard capacity
//! and a depth counter the router reads for power-of-two-choices
//! placement.

use crate::util::sync::lock_unpoisoned;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

#[derive(Debug)]
pub enum QueueError<T> {
    /// Queue at capacity — caller must back off.
    Full(T),
    /// Queue closed (shutdown).
    Closed,
}

/// Bounded MPSC queue.
pub struct BoundedQueue<T> {
    inner: Mutex<VecDeque<T>>,
    cap: usize,
    depth: AtomicUsize,
    closed: AtomicBool,
    signal: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            inner: Mutex::new(VecDeque::with_capacity(cap)),
            cap,
            depth: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            signal: Condvar::new(),
        }
    }

    /// Current depth (approximate; used for load-aware routing).
    #[inline]
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Non-blocking push; rejects when full or closed.
    pub fn push(&self, item: T) -> Result<(), QueueError<T>> {
        if self.is_closed() {
            return Err(QueueError::Closed);
        }
        let mut q = lock_unpoisoned(&self.inner, "bounded queue");
        if q.len() >= self.cap {
            return Err(QueueError::Full(item));
        }
        q.push_back(item);
        self.depth.store(q.len(), Ordering::Relaxed);
        drop(q);
        self.signal.notify_one();
        Ok(())
    }

    /// Pop one item, waiting up to `timeout`; None on timeout or when
    /// closed-and-empty.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut q = lock_unpoisoned(&self.inner, "bounded queue");
        loop {
            if let Some(item) = q.pop_front() {
                self.depth.store(q.len(), Ordering::Relaxed);
                return Some(item);
            }
            if self.is_closed() {
                return None;
            }
            let (guard, res) = match self.signal.wait_timeout(q, timeout) {
                Ok(pair) => pair,
                Err(_) => panic!("invariant: bounded queue mutex is never poisoned"),
            };
            q = guard;
            if res.timed_out() {
                let item = q.pop_front();
                if item.is_some() {
                    self.depth.store(q.len(), Ordering::Relaxed);
                }
                return item;
            }
        }
    }

    /// Drain up to `max` immediately-available items into `out`
    /// (batch formation fast path; no waiting).
    pub fn drain_into(&self, out: &mut Vec<T>, max: usize) {
        let mut q = lock_unpoisoned(&self.inner, "bounded queue");
        while out.len() < max {
            match q.pop_front() {
                Some(item) => out.push(item),
                None => break,
            }
        }
        self.depth.store(q.len(), Ordering::Relaxed);
    }

    /// Close: subsequent pushes fail; poppers drain whatever remains.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.signal.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn rejects_when_full() {
        let q = BoundedQueue::new(2);
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        match q.push(3) {
            Err(QueueError::Full(v)) => assert_eq!(v, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn close_drains_then_stops() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert!(matches!(q.push(2), Err(QueueError::Closed)));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn producer_consumer_threads() {
        let q = Arc::new(BoundedQueue::new(64));
        let qc = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut got = 0u64;
            while let Some(v) = qc.pop_timeout(Duration::from_millis(200)) {
                got += v;
            }
            got
        });
        let mut sent = 0u64;
        for i in 1..=1000u64 {
            loop {
                match q.push(i) {
                    Ok(()) => {
                        sent += i;
                        break;
                    }
                    Err(QueueError::Full(_)) => std::thread::yield_now(),
                    Err(QueueError::Closed) => panic!("closed early"),
                }
            }
        }
        q.close();
        assert_eq!(consumer.join().unwrap(), sent);
    }

    #[test]
    fn drain_into_takes_at_most_max() {
        let q = BoundedQueue::new(16);
        for i in 0..10 {
            q.push(i).unwrap();
        }
        let mut out = Vec::new();
        q.drain_into(&mut out, 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(q.depth(), 6);
    }
}
