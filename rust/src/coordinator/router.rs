//! Query routing: power-of-two-choices over the shard queues.
//!
//! The sketch store is replicated (read-mostly Arc snapshot) so any
//! worker can serve any pair; routing is purely a load-balancing
//! decision. Two random queues are probed and the shallower one wins —
//! the classic d=2 trick gets exponentially better max-load than random
//! placement with only two depth reads, and it *self-rebalances* when a
//! worker stalls (its queue deepens, traffic drains to the others).

use super::backpressure::{BoundedQueue, QueueError};
use super::Job;
use crate::numerics::SplitMix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub struct Router {
    queues: Vec<Arc<BoundedQueue<Job>>>,
    counter: AtomicU64,
    seed: u64,
}

impl Router {
    pub(crate) fn new(queues: Vec<Arc<BoundedQueue<Job>>>, seed: u64) -> Self {
        assert!(!queues.is_empty());
        Self {
            queues,
            counter: AtomicU64::new(0),
            seed,
        }
    }

    pub fn shards(&self) -> usize {
        self.queues.len()
    }

    /// Route a job to the less-loaded of two pseudo-random shards;
    /// on Full, retry the other, then fail (explicit backpressure).
    pub(crate) fn route(&self, job: Job) -> Result<(), QueueError<Job>> {
        let n = self.queues.len();
        if n == 1 {
            return self.queues[0].push(job);
        }
        let c = self.counter.fetch_add(1, Ordering::Relaxed);
        let h = SplitMix64::hash(self.seed, c);
        let a = (h % n as u64) as usize;
        let b = ((h >> 32) % n as u64) as usize;
        let (first, second) = if self.queues[a].depth() <= self.queues[b].depth() {
            (a, b)
        } else {
            (b, a)
        };
        match self.queues[first].push(job) {
            Ok(()) => Ok(()),
            Err(QueueError::Full(job)) => self.queues[second].push(job),
            Err(e) => Err(e),
        }
    }

    /// Queue depths (diagnostics).
    pub fn depths(&self) -> Vec<usize> {
        self.queues.iter().map(|q| q.depth()).collect()
    }

    pub fn close_all(&self) {
        for q in &self.queues {
            q.close();
        }
    }
}
