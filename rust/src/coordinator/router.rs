//! Query routing: power-of-two-choices over the shard queues.
//!
//! The sketch store is replicated (read-mostly Arc snapshot) so any
//! worker can serve any pair; routing is purely a load-balancing
//! decision. Two random queues are probed and the shallower one wins —
//! the classic d=2 trick gets exponentially better max-load than random
//! placement with only two depth reads, and it *self-rebalances* when a
//! worker stalls (its queue deepens, traffic drains to the others).

use super::backpressure::{BoundedQueue, QueueError};
use super::Job;
use crate::numerics::SplitMix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub struct Router {
    queues: Vec<Arc<BoundedQueue<Job>>>,
    counter: AtomicU64,
    seed: u64,
}

impl Router {
    pub(crate) fn new(queues: Vec<Arc<BoundedQueue<Job>>>, seed: u64) -> Self {
        assert!(!queues.is_empty());
        Self {
            queues,
            counter: AtomicU64::new(0),
            seed,
        }
    }

    pub fn shards(&self) -> usize {
        self.queues.len()
    }

    /// Route a job to the less-loaded of two pseudo-random shards;
    /// on Full, retry the other, then fail (explicit backpressure).
    pub(crate) fn route(&self, job: Job) -> Result<(), QueueError<Job>> {
        let n = self.queues.len();
        if n == 1 {
            return self.queues[0].push(job);
        }
        let c = self.counter.fetch_add(1, Ordering::Relaxed);
        let h = SplitMix64::hash(self.seed, c);
        let a = (h % n as u64) as usize;
        // The second probe must be *distinct*: drawing it independently
        // from the high half of the hash can collide with `a`, and then
        // the Full-retry pushes the same full queue twice — reporting
        // backpressure while another queue sits empty. Offsetting by
        // 1 + (h_hi mod n−1) keeps b uniform over the other n−1 queues.
        let b = (a + 1 + ((h >> 32) % (n as u64 - 1)) as usize) % n;
        let (first, second) = if self.queues[a].depth() <= self.queues[b].depth() {
            (a, b)
        } else {
            (b, a)
        };
        match self.queues[first].push(job) {
            Ok(()) => Ok(()),
            Err(QueueError::Full(job)) => self.queues[second].push(job),
            Err(e) => Err(e),
        }
    }

    /// Queue depths (diagnostics).
    pub fn depths(&self) -> Vec<usize> {
        self.queues.iter().map(|q| q.depth()).collect()
    }

    pub fn close_all(&self) {
        for q in &self.queues {
            q.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Query, QueryKind};
    use std::sync::mpsc;
    use std::time::Instant;

    fn job(
        reply: &mpsc::Sender<(
            usize,
            crate::coordinator::Reply,
            crate::coordinator::TraceSpans,
        )>,
    ) -> Job {
        Job {
            query: Query::Pair {
                i: 0,
                j: 1,
                kind: QueryKind::Oq,
            },
            seq: 0,
            epoch: 0,
            trace: crate::coordinator::TraceSpans::default(),
            submitted: Instant::now(),
            reply: crate::coordinator::ReplyTo::Channel(reply.clone()),
        }
    }

    /// Regression for the probe-collision bug: with one full queue and
    /// one empty queue, routing must never fail. Before forcing the
    /// second probe distinct, both probes could land on the full queue
    /// (low and high hash halves colliding), and the Full-retry pushed
    /// the *same* full queue twice — spurious backpressure while the
    /// other queue sat empty.
    #[test]
    fn one_full_one_empty_queue_never_fails_to_route() {
        let full = Arc::new(BoundedQueue::new(4));
        let empty = Arc::new(BoundedQueue::new(1024));
        let (tx, _rx) = mpsc::channel();
        for _ in 0..4 {
            full.push(job(&tx)).expect("prefill");
        }
        let router = Router::new(vec![full.clone(), empty.clone()], 0xDECAF);
        for r in 0..512 {
            router.route(job(&tx)).unwrap_or_else(|_| {
                panic!("route {r} failed with an empty queue available")
            });
        }
        assert_eq!(full.depth(), 4, "full queue untouched");
        assert_eq!(empty.depth(), 512, "all jobs landed on the empty queue");
    }

    /// The distinct-probe construction covers every queue pair, not
    /// just adjacent ones: over many routes on idle equal-depth queues,
    /// every queue receives traffic.
    #[test]
    fn probes_spread_over_all_queues() {
        let queues: Vec<_> = (0..5).map(|_| Arc::new(BoundedQueue::new(4096))).collect();
        let (tx, _rx) = mpsc::channel();
        let router = Router::new(queues.clone(), 7);
        for _ in 0..2_000 {
            router.route(job(&tx)).expect("route");
        }
        for (i, q) in queues.iter().enumerate() {
            assert!(q.depth() > 0, "queue {i} never chosen");
        }
    }
}
