//! Dynamic batching: a worker forms a batch by taking the first job
//! (waiting up to the poll timeout), then greedily draining whatever is
//! already queued up to `max_batch`, then — if still under-filled and
//! young — waiting out the remaining deadline for stragglers.
//!
//! Size-or-deadline batching amortizes per-batch costs (buffer reuse,
//! snapshot acquisition, cache warmth over the sketch rows) without
//! adding unbounded latency at low load; the deadline bounds the
//! worst-case queueing delay a lone query sees.

use super::backpressure::BoundedQueue;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 64,
            deadline: Duration::from_micros(200),
        }
    }
}

/// Stateless batch former over a queue.
pub struct Batcher {
    policy: BatchPolicy,
    poll: Duration,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            policy,
            poll: Duration::from_millis(20),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Form the next batch. Returns an empty vec only when the queue is
    /// closed and drained (worker exit signal).
    pub fn next_batch<T>(&self, queue: &Arc<BoundedQueue<T>>, out: &mut Vec<T>) {
        out.clear();
        // Block for the first element.
        loop {
            match queue.pop_timeout(self.poll) {
                Some(first) => {
                    out.push(first);
                    break;
                }
                None => {
                    if queue.is_closed() {
                        return; // empty = shut down
                    }
                }
            }
        }
        // Greedy drain of already-waiting jobs.
        queue.drain_into(out, self.policy.max_batch);
        if out.len() >= self.policy.max_batch {
            return;
        }
        // Straggler window.
        let formed = Instant::now();
        while out.len() < self.policy.max_batch {
            let left = self.policy.deadline.checked_sub(formed.elapsed());
            let Some(left) = left else { break };
            match queue.pop_timeout(left) {
                Some(job) => out.push(job),
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_whats_waiting() {
        let q = Arc::new(BoundedQueue::new(128));
        for i in 0..10 {
            q.push(i).unwrap();
        }
        let b = Batcher::new(BatchPolicy {
            max_batch: 4,
            deadline: Duration::from_micros(50),
        });
        let mut batch = Vec::new();
        b.next_batch(&q, &mut batch);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        b.next_batch(&q, &mut batch);
        assert_eq!(batch, vec![4, 5, 6, 7]);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(16));
        q.push(1).unwrap();
        let b = Batcher::new(BatchPolicy {
            max_batch: 64,
            deadline: Duration::from_millis(5),
        });
        let t0 = Instant::now();
        let mut batch = Vec::new();
        b.next_batch(&q, &mut batch);
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn empty_batch_signals_shutdown() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        q.close();
        let b = Batcher::new(BatchPolicy::default());
        let mut batch = vec![99];
        b.next_batch(&q, &mut batch);
        assert!(batch.is_empty());
    }

    #[test]
    fn straggler_window_collects_late_arrivals() {
        let q = Arc::new(BoundedQueue::new(16));
        q.push(1u32).unwrap();
        let qc = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            qc.push(2).unwrap();
        });
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            deadline: Duration::from_millis(50),
        });
        let mut batch = Vec::new();
        b.next_batch(&q, &mut batch);
        producer.join().unwrap();
        assert_eq!(batch, vec![1, 2]);
    }
}
