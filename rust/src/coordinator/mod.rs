//! The L3 coordinator: a sharded, batching, backpressured serving
//! pipeline executing **query plans** over the sketch store.
//!
//! Topology:
//!
//! ```text
//!           ┌──────────── ClientHandle (clone-able) ────────────┐
//!           │ query plan: Pair | TopK | Block  → multi-value    │
//!           │ replies; router: power-of-two-choices over shards │
//!           └──────┬───────────────┬───────────────┬────────────┘
//!   bounded queue  ▼               ▼               ▼   (backpressure:
//!            [ shard 0 ]     [ shard 1 ]     [ shard 2 ]  reject when full)
//!            worker thread   worker thread   worker thread
//!            dynamic batcher (size + deadline)
//!            fused abs-diff-select kernel: f32 scan, one reused
//!            scratch + one estimator per batch, no per-query copy
//!                  ▲ read-mostly Arc<SketchStore> snapshots
//!  ingest thread ──┘ turnstile events → new snapshot per epoch
//! ```
//!
//! A [`Query`] is one unit of routing/batching: a single [`Query::Pair`]
//! distance, a [`Query::TopK`] one-vs-all nearest-neighbour scan, or a
//! [`Query::Block`] distance sub-matrix. TopK/Block amortize one store
//! snapshot and one scratch across every candidate — the workload shape
//! (kNN, pairwise blocks) the paper's cheap estimator exists for.
//!
//! Distances are estimated with the optimal quantile estimator by
//! default (select + one pow — the paper's point is that this is cheap
//! enough to sit on a serving hot path); gm/fp/median are available
//! per-query for comparison workloads, all through the same fused
//! kernel (`estimators::batch`) so the comparison stays fair.

// Enforced by pallas-lint (PL002) and re-stated to the compiler: this
// module (and its children) must stay free of unsafe code.
#![forbid(unsafe_code)]

mod backpressure;
mod batcher;
mod router;
mod shard;
mod worker;

pub use backpressure::{BoundedQueue, QueueError};
pub use batcher::{BatchPolicy, Batcher};
pub use router::Router;
pub use shard::{ReplicaMove, ReplicaSet, ShardSet};

use crate::estimators::{
    FractionalPower, FusedDiffEstimator, GeometricMean, OptimalQuantile, QuantileEstimator,
};
use crate::metrics::PipelineMetrics;
use crate::sketch::{SketchDtype, SketchStore, StreamEvent, StreamingSketcher};
use crate::trace::{TraceBuf, TraceRecord};
use crate::util::config::PipelineConfig;
use crate::util::sync::lock_unpoisoned;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which estimator serves a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryKind {
    /// Optimal quantile (default; the paper's contribution).
    Oq,
    /// Geometric mean (k pow baseline).
    Gm,
    /// Fractional power.
    Fp,
    /// Sample median (Indyk baseline).
    Median,
    /// Sign collision probability over bit-packed 1-bit sketches
    /// (XOR + popcount; 1308.1009). Valid only against a
    /// [`SketchDtype::SignBits`] store — admission refuses it on a
    /// dense store, and refuses the dense kinds on a sign store.
    Sign,
}

impl QueryKind {
    /// Stable index into the per-kind metrics arrays
    /// (`metrics::KIND_LABELS` order).
    pub fn index(self) -> usize {
        match self {
            QueryKind::Oq => 0,
            QueryKind::Gm => 1,
            QueryKind::Fp => 2,
            QueryKind::Median => 3,
            QueryKind::Sign => 4,
        }
    }

    /// Inverse of [`Self::index`] — the wire protocol and CLI decode
    /// kinds through this so the mapping stays in one place.
    pub fn from_index(ix: usize) -> Option<QueryKind> {
        match ix {
            0 => Some(QueryKind::Oq),
            1 => Some(QueryKind::Gm),
            2 => Some(QueryKind::Fp),
            3 => Some(QueryKind::Median),
            4 => Some(QueryKind::Sign),
            _ => None,
        }
    }

    /// Parse a kind label (`oq|gm|fp|median|sign`), as printed by
    /// [`Self::label`].
    pub fn parse(s: &str) -> Option<QueryKind> {
        match s {
            "oq" => Some(QueryKind::Oq),
            "gm" => Some(QueryKind::Gm),
            "fp" => Some(QueryKind::Fp),
            "median" | "med" => Some(QueryKind::Median),
            "sign" => Some(QueryKind::Sign),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        crate::metrics::KIND_LABELS[self.index()]
    }
}

/// One unit of the query plan — what the router places and a worker
/// executes under a single store snapshot with a single reused scratch.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    /// One pairwise distance.
    Pair { i: u32, j: u32, kind: QueryKind },
    /// The `m` nearest neighbours of row `i` by estimated l_α distance
    /// (one-vs-all fused scan; `m` is clamped to n−1).
    TopK { i: u32, m: usize, kind: QueryKind },
    /// The `rows × cols` distance sub-matrix (row-major reply). A block
    /// is one routing unit, so its size is capped at
    /// [`MAX_BLOCK_CELLS`] cells — larger requests must be split into
    /// several block queries (which then batch/balance normally).
    Block {
        rows: Vec<u32>,
        cols: Vec<u32>,
        kind: QueryKind,
    },
}

/// Upper bound on `rows.len() × cols.len()` for one [`Query::Block`].
/// Backpressure accounts per queue slot; without this cap a single
/// admitted block could pin a shard for an unbounded scan and allocate
/// an unbounded reply. 2²⁰ cells ≈ 8 MiB of reply per slot.
pub const MAX_BLOCK_CELLS: usize = 1 << 20;

impl Query {
    pub fn kind(&self) -> QueryKind {
        match self {
            Query::Pair { kind, .. } | Query::TopK { kind, .. } | Query::Block { kind, .. } => {
                *kind
            }
        }
    }
}

/// Which slice of the cluster's row space this process owns — `index`
/// of `of` contiguous even shards (`serve --listen --shard i/of`).
///
/// A sharded node still holds the *full* replicated sketch store (the
/// store is the cheap part — `n × k` f32; sketching is deterministic
/// per row, so every node derives identical sketches from the shared
/// seed). What the spec partitions is the *compute*: a `TopK` on a
/// sharded node scans only the owned candidate range, and the cluster
/// client routes `Pair`s to the owner and splits `Block` rows by
/// ownership — so an N-node cluster does 1/N of the scan work per node
/// while every served distance stays bit-identical to a single node's.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// This node's shard index, `0 ≤ index < of`.
    pub index: usize,
    /// Total shards in the cluster.
    pub of: usize,
}

/// Shared parser for the `i/of` CLI slot syntax behind
/// [`ShardSpec::parse`] and [`ReplicaSpec::parse`] — one place for the
/// separator and the `of ≥ 1 && index < of` validity rule, so the two
/// spec types cannot drift apart.
fn parse_slot(s: &str) -> Option<(usize, usize)> {
    let (i, of) = s.split_once('/')?;
    let index: usize = i.trim().parse().ok()?;
    let of: usize = of.trim().parse().ok()?;
    (of >= 1 && index < of).then_some((index, of))
}

impl ShardSpec {
    /// Parse the CLI form `i/of` (e.g. `--shard 1/3`). `of ≥ 1` and
    /// `index < of`.
    pub fn parse(s: &str) -> Option<ShardSpec> {
        let (index, of) = parse_slot(s)?;
        Some(ShardSpec { index, of })
    }

    /// The rows this shard owns out of `n` total (even contiguous
    /// split — the map every node and the cluster client agree on).
    pub fn owned_range(&self, n: usize) -> std::ops::Range<usize> {
        ShardSet::even(n, self.of).range(self.index)
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.of)
    }
}

/// Which replica of its row range this process is — `index` of `of`
/// siblings serving the *same* rows (`serve --listen --shard i/S
/// --replica r/R`). Replication multiplies the node count: an S-shard
/// R-replica cluster is `S × R` processes, and the cluster client
/// routes each sub-plan to one live sibling per range, failing over to
/// another when a node dies mid-stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaSpec {
    /// This node's replica index, `0 ≤ index < of`.
    pub index: usize,
    /// Replication factor: how many nodes serve this row range.
    pub of: usize,
}

impl ReplicaSpec {
    /// The unreplicated default: the only copy of its range.
    pub fn solo() -> ReplicaSpec {
        ReplicaSpec { index: 0, of: 1 }
    }

    /// Parse the CLI form `r/R` (e.g. `--replica 1/2`). `R ≥ 1` and
    /// `index < R`.
    pub fn parse(s: &str) -> Option<ReplicaSpec> {
        let (index, of) = parse_slot(s)?;
        Some(ReplicaSpec { index, of })
    }
}

impl Default for ReplicaSpec {
    fn default() -> Self {
        Self::solo()
    }
}

impl std::fmt::Display for ReplicaSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.of)
    }
}

/// The single-pair convenience form (the original query model); any
/// `PairQuery` is just a `Query::Pair`.
#[derive(Debug, Clone, Copy)]
pub struct PairQuery {
    pub i: u32,
    pub j: u32,
    pub kind: QueryKind,
}

impl From<PairQuery> for Query {
    fn from(q: PairQuery) -> Query {
        Query::Pair {
            i: q.i,
            j: q.j,
            kind: q.kind,
        }
    }
}

/// One query's answer, shape-matched to its [`Query`] variant.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    Pair(f64),
    /// `(candidate row, distance)` sorted ascending by distance.
    TopK(Vec<(u32, f64)>),
    /// Row-major `rows × cols` distances.
    Block(Vec<f64>),
    /// Refusal, not an answer: the query's shard-map epoch stamp
    /// became unresolvable while it sat in a worker queue (two
    /// adoptions landed inside its residence — the one-level history
    /// in [`Ownership`] no longer covers it). Answering under the
    /// current range would silently change coverage, so the worker
    /// refuses; the network layer forwards this as a `WrongEpoch`
    /// error frame and the cluster client refreshes and retries.
    /// Unstamped (epoch 0) queries can never produce it.
    WrongEpoch { current: u64 },
}

impl Reply {
    /// The pair distance, or `None` on a shape mismatch. Library code
    /// (and the network reply path, where a mismatch must become a
    /// protocol error, not a crash) goes through this.
    pub fn try_pair(&self) -> Option<f64> {
        match self {
            Reply::Pair(d) => Some(*d),
            _ => None,
        }
    }

    /// The TopK candidate list, or `None` on a shape mismatch.
    pub fn try_top_k(self) -> Option<Vec<(u32, f64)>> {
        match self {
            Reply::TopK(v) => Some(v),
            _ => None,
        }
    }

    /// The row-major block distances, or `None` on a shape mismatch.
    pub fn try_block(self) -> Option<Vec<f64>> {
        match self {
            Reply::Block(v) => Some(v),
            _ => None,
        }
    }

    /// The pair distance, for plans known to be all-`Pair`.
    ///
    /// Panics on a shape mismatch — use [`Self::try_pair`] anywhere a
    /// mismatch is reachable from input data.
    pub fn pair(self) -> f64 {
        match self.try_pair() {
            Some(d) => d,
            None => panic!("expected a Pair reply, got {self:?}"),
        }
    }
}

/// Why [`Coordinator::submit`] refused a query — typed so callers (the
/// network listener in particular) can map each case to a distinct
/// wire-level reply instead of parsing error strings.
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    /// The query failed admission validation (out-of-range row,
    /// oversized block, …).
    #[error("invalid query: {0}")]
    Invalid(String),
    /// Every candidate shard queue is full — shed load or retry.
    #[error("backpressure: shard queues full")]
    Overloaded,
    /// The query was stamped with a shard-map epoch that is not this
    /// node's current one — the caller's map is stale; it should
    /// re-run the shard-map exchange and retry.
    #[error("wrong shard-map epoch (node is at {current})")]
    WrongEpoch { current: u64 },
    /// The pipeline has shut down.
    #[error("pipeline is shut down")]
    Shutdown,
}

/// Per-query span accumulator, threaded from admission to the reply
/// write alongside the reply itself. `trace_id == 0` is the untraced
/// fast path: the worker still copies the stage timings in (they are
/// timestamps it already takes for the latency histograms — no extra
/// clock reads), and the completion site decides whether anything is
/// retained (trace ring for traced queries, slow-query log for
/// anything over threshold).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceSpans {
    /// Client-chosen v6 trace id (0 = untraced).
    pub trace_id: u64,
    /// Frame-parse time, stamped by the network listener (0 for
    /// in-process plans).
    pub decode_ns: u64,
    /// Admission → worker pickup, stamped by the worker.
    pub queue_ns: u64,
    /// Worker execute (scan + kernel), stamped by the worker.
    pub scan_ns: u64,
}

/// One finished query, as delivered to a [`CompletionQueue`]: the
/// submitter's correlation tag, the worker's reply, and the accumulated
/// trace spans, plus the opaque connection token the submitter attached
/// so a queue shared by many connections can route each completion back
/// to its owner.
#[derive(Debug)]
pub struct Completion {
    /// Submitter-chosen token identifying the owning connection.
    pub conn: u64,
    /// Submitter-chosen correlation tag (the wire frame id).
    pub tag: usize,
    pub reply: Reply,
    pub spans: TraceSpans,
}

/// A wakeup-capable completion mailbox: workers push finished queries,
/// then fire the wake callback so the owning event loop (parked in
/// `poll(2)`) comes back and drains. This replaces the
/// blocking-forwarder-thread reply path for the readiness-driven
/// server.
///
/// Contract (relied on by `server::listener`):
/// - `push` never blocks: the queue is unbounded, bounded in practice
///   by the submitter's own inflight cap (the listener stops reading a
///   connection at `MAX_CONN_INFLIGHT` outstanding queries, so the
///   queue holds at most inflight-cap × connections entries).
/// - The wake callback runs on the *worker* thread after the
///   completion is visible in the queue, so a loop that drains after
///   waking can never miss one; it must therefore be cheap and
///   nonblocking (the reactor's self-pipe write is both).
/// - `drain` hands back completions in push order.
pub struct CompletionQueue {
    queue: Mutex<Vec<Completion>>,
    wake: Box<dyn Fn() + Send + Sync>,
}

impl CompletionQueue {
    /// Build a queue whose `wake` is invoked (after the push is
    /// visible) every time a completion arrives.
    pub fn new(wake: impl Fn() + Send + Sync + 'static) -> Arc<Self> {
        Arc::new(Self {
            queue: Mutex::new(Vec::new()),
            wake: Box::new(wake),
        })
    }

    /// Deliver one completion and fire the wakeup. Called from worker
    /// threads; never blocks beyond the queue mutex.
    pub fn push(&self, c: Completion) {
        lock_unpoisoned(&self.queue, "completion").push(c);
        (self.wake)();
    }

    /// Take everything delivered so far, in push order. Called by the
    /// owning event loop after a wakeup (spurious drains return empty).
    pub fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *lock_unpoisoned(&self.queue, "completion"))
    }
}

impl std::fmt::Debug for CompletionQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let depth = self.queue.lock().map(|q| q.len()).unwrap_or(0);
        f.debug_struct("CompletionQueue").field("depth", &depth).finish()
    }
}

/// Where a job's reply goes: the blocking channel path (in-process
/// plans, tests) or a completion queue that wakes an event loop (the
/// network server). Workers call [`ReplyTo::send`] without knowing
/// which; both are fire-and-forget from the worker's side.
#[derive(Debug, Clone)]
pub(crate) enum ReplyTo {
    Channel(std::sync::mpsc::Sender<(usize, Reply, TraceSpans)>),
    Completion { queue: Arc<CompletionQueue>, conn: u64 },
}

impl ReplyTo {
    pub fn send(&self, tag: usize, reply: Reply, spans: TraceSpans) {
        match self {
            // A dropped receiver means the submitter gave up (connection
            // closed); the reply is discarded, same as before.
            ReplyTo::Channel(tx) => {
                let _ = tx.send((tag, reply, spans));
            }
            ReplyTo::Completion { queue, conn } => queue.push(Completion {
                conn: *conn,
                tag,
                reply,
                spans,
            }),
        }
    }
}

#[derive(Debug)]
pub(crate) struct Job {
    pub query: Query,
    pub seq: usize,
    /// Shard-map epoch the submitter routed under (0 = unstamped,
    /// never checked). Workers resolve the candidate range for this
    /// epoch, so queries admitted just before an adoption still finish
    /// under the map they were routed with.
    pub epoch: u64,
    /// Trace identity + decode span from the submitter; the worker
    /// fills the queue/scan spans and hands the whole thing back with
    /// the reply.
    pub trace: TraceSpans,
    pub submitted: Instant,
    pub reply: ReplyTo,
}

/// This node's live shard ownership: the map epoch, the shard identity
/// advertised to clients, and the candidate-row range `TopK` scans.
/// Swapped atomically (under its mutex) by [`Coordinator::adopt_shard`];
/// workers snapshot it once per batch, so a batch never sees a torn
/// range.
#[derive(Debug, Clone)]
pub(crate) struct Ownership {
    /// Monotonically increasing shard-map epoch. 0 = static (an
    /// unclustered node, or a pre-v4 peer's view).
    pub epoch: u64,
    /// Shard identity (None = unsharded, owns everything).
    pub spec: Option<ShardSpec>,
    /// Replica identity within the shard's replica set (`solo()` on an
    /// unreplicated node). Advertised through `ShardMap` frames so the
    /// cluster client can place this node in its `(shard, replica)`
    /// grid; it does not affect what the workers scan — siblings serve
    /// identical ranges by construction.
    pub replica: ReplicaSpec,
    /// The candidate-row range `TopK` scans (clamped to the live
    /// store's n at scan time). `0..usize::MAX` on an unsharded node —
    /// i.e. every row, including ones ingested after start.
    pub owned: std::ops::Range<usize>,
    /// The immediately previous `(epoch, range)`: queries stamped with
    /// it that were admitted before an adoption swap still execute
    /// under it, so an in-flight plan finishes under the old epoch
    /// instead of silently changing coverage mid-plan. One level of
    /// history only — a query that outlives *two* adoptions resolves
    /// to no range at all and is refused with [`Reply::WrongEpoch`]
    /// (never silently answered under a map it was not routed with).
    pub prev: Option<(u64, std::ops::Range<usize>)>,
}

impl Ownership {
    /// The candidate range for a query stamped with `epoch`: 0 and the
    /// current epoch resolve to the live range, the retained previous
    /// epoch to its range. `None` for anything else — the map that
    /// query was routed with is gone (two adoptions landed inside its
    /// queue residence), and answering under a *different* range would
    /// silently change coverage; the caller must refuse instead.
    pub fn range_for(&self, epoch: u64) -> Option<std::ops::Range<usize>> {
        if epoch == 0 || epoch == self.epoch {
            return Some(self.owned.clone());
        }
        match &self.prev {
            Some((e, r)) if *e == epoch => Some(r.clone()),
            _ => None,
        }
    }
}

/// Why [`Coordinator::adopt_shard`] refused a new shard identity.
#[derive(Debug, thiserror::Error)]
pub enum AdoptError {
    /// The adoption's epoch is not strictly newer than the node's
    /// current one — a stale admin raced a fresher reconfiguration.
    #[error("stale shard adoption: node is already at epoch {current}")]
    Stale { current: u64 },
    /// The proposed geometry makes no sense for this node's store.
    #[error("invalid shard adoption: {0}")]
    Invalid(String),
}

/// Everything a worker needs, shared.
pub(crate) struct Shared {
    pub store: Mutex<Arc<SketchStore>>, // swapped by ingest epochs
    /// Live shard ownership (epoch + owned range), swapped by
    /// [`Coordinator::adopt_shard`] and snapshotted per worker batch.
    pub ownership: Mutex<Ownership>,
    /// The current shard-map epoch, mirrored atomically so per-query
    /// admission (the network hot path) does not serialize on the
    /// ownership mutex.
    pub epoch: std::sync::atomic::AtomicU64,
    /// Row count of the published snapshot, mirrored atomically so the
    /// per-query admission check ([`Coordinator::submit`] — the
    /// network hot path, one call per connection-reader query) does
    /// not serialize on the store mutex.
    pub store_n: AtomicUsize,
    pub oq: OptimalQuantile,
    pub gm: GeometricMean,
    pub fp: FractionalPower,
    pub median: QuantileEstimator,
    pub metrics: PipelineMetrics,
    /// The representation of the served store, fixed at start: ingest
    /// never changes it (it is refused outright on a sign-bits store),
    /// so per-query admission can check kind-vs-dtype without touching
    /// the store mutex.
    pub dtype: SketchDtype,
    /// Per-node trace retention: completed traced queries + the
    /// slow-query log (see [`crate::trace::TraceBuf`]).
    pub traces: TraceBuf,
    pub stop: AtomicBool,
    /// In-node fan-out for one worker's TopK/Block scan (resolved from
    /// `PipelineConfig::scan_threads` at start; always ≥ 1). Scans
    /// below the `SketchStore::PAR_MIN_*` thresholds stay sequential
    /// regardless, so this is a ceiling, not a promise.
    pub scan_threads: usize,
}

impl Shared {
    pub fn snapshot(&self) -> Arc<SketchStore> {
        lock_unpoisoned(&self.store, "store").clone()
    }

    /// The fused estimator serving a query kind. `Sync` is part of the
    /// contract: the node-local parallel scans share one estimator
    /// across their scoped sub-threads.
    #[inline]
    pub fn fused(&self, kind: QueryKind) -> &(dyn FusedDiffEstimator + Sync) {
        match kind {
            QueryKind::Oq => &self.oq,
            QueryKind::Gm => &self.gm,
            QueryKind::Fp => &self.fp,
            QueryKind::Median => &self.median,
            // Admission pairs Sign with sign-bits stores only, and the
            // worker dispatches those to the popcount path before ever
            // asking for a fused f32 estimator.
            QueryKind::Sign => unreachable!("sign queries do not use a fused f32 estimator"),
        }
    }
}

/// The running pipeline.
pub struct Coordinator {
    shared: Arc<Shared>,
    router: Router,
    workers: Vec<std::thread::JoinHandle<()>>,
    ingest: Mutex<StreamingSketcher>,
    config: PipelineConfig,
    started: Instant,
}

impl Coordinator {
    /// Start workers over an existing sketch store, serving every row
    /// (a single-node deployment, or one not yet clustered).
    pub fn start(config: PipelineConfig, store: SketchStore) -> Result<Coordinator> {
        Self::start_sharded(config, store, None)
    }

    /// Start workers owning only the row slice of `shard` (when given)
    /// — one node of a multi-process cluster. The store passed in is
    /// still the full replicated store (see [`ShardSpec`]); `shard`
    /// restricts the `TopK` candidate scan and is advertised to
    /// clients through the wire protocol's `ShardMap` frame.
    pub fn start_sharded(
        config: PipelineConfig,
        store: SketchStore,
        shard: Option<ShardSpec>,
    ) -> Result<Coordinator> {
        Self::start_replicated(config, store, shard, ReplicaSpec::solo())
    }

    /// [`Self::start_sharded`] with a replica identity: this process is
    /// replica `replica.index` of `replica.of` siblings all owning the
    /// same row range (`serve --listen --shard i/S --replica r/R`).
    /// Replication changes nothing about what the workers scan — it is
    /// advertised through the v5 `ShardMap` frame so the cluster
    /// client can fail over between siblings. A replicated node always
    /// participates in the epoch machinery (a replicated-but-unsharded
    /// deployment is one shard of 1), so sweeps can reconfigure the
    /// whole replica set.
    pub fn start_replicated(
        config: PipelineConfig,
        store: SketchStore,
        shard: Option<ShardSpec>,
        replica: ReplicaSpec,
    ) -> Result<Coordinator> {
        if store.k != config.k {
            bail!("store k={} != config k={}", store.k, config.k);
        }
        if let Some(s) = shard {
            if s.of == 0 || s.index >= s.of {
                bail!("invalid shard spec {}/{}", s.index, s.of);
            }
        }
        if replica.of == 0 || replica.index >= replica.of {
            bail!("invalid replica spec {}/{}", replica.index, replica.of);
        }
        let alpha = config.alpha;
        let k = config.k;
        let n = store.n;
        let dtype = store.dtype();
        let store_bytes = store.memory_bytes();
        // R > 1 without --shard: one shard of 1, replicated — the
        // epoch stamps must engage so the siblings can be swept. The
        // scan range stays open-ended (0..usize::MAX) like the solo
        // node this generalizes: the node owns *everything*, including
        // rows ingested after start — only an explicit shard spec pins
        // the range to the start-time split.
        let (shard, owned) = match (shard, replica.of) {
            (Some(s), _) => (Some(s), s.owned_range(n)),
            (None, of) if of > 1 => (Some(ShardSpec { index: 0, of: 1 }), 0..usize::MAX),
            (None, _) => (None, 0..usize::MAX),
        };
        // A clustered node starts at epoch 1 so clients' epoch stamps
        // engage; an unsharded node's map is static (epoch 0, never
        // checked) until an adoption pulls it into a cluster.
        let epoch = u64::from(shard.is_some());
        // A sign-bits node refuses ingest outright, so don't let its
        // sketcher allocate the dense n×k shadow store a dense node's
        // ingest path maintains (that buffer alone would be 32× the
        // bit-packed store it sits next to).
        let ingest_rows = if dtype == SketchDtype::DenseF32 { n } else { 0 };
        let ingest = StreamingSketcher::new(alpha, config.dim, k, config.seed, ingest_rows);
        // 0 = auto: a small in-node thread set, capped so a node running
        // several shard workers doesn't oversubscribe the host.
        let scan_threads = if config.scan_threads > 0 {
            config.scan_threads
        } else {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
                .min(4)
        };
        let shared = Arc::new(Shared {
            store_n: AtomicUsize::new(n),
            store: Mutex::new(Arc::new(store)),
            ownership: Mutex::new(Ownership {
                epoch,
                spec: shard,
                replica,
                owned,
                prev: None,
            }),
            epoch: std::sync::atomic::AtomicU64::new(epoch),
            oq: OptimalQuantile::new(alpha, k),
            gm: GeometricMean::new(alpha, k),
            fp: FractionalPower::new(alpha, k),
            median: QuantileEstimator::median(alpha, k),
            metrics: PipelineMetrics::default(),
            dtype,
            traces: TraceBuf::new(),
            stop: AtomicBool::new(false),
            scan_threads,
        });
        shared
            .metrics
            .kernel_lanes_used
            .set(crate::estimators::KERNEL_LANES as i64);
        shared
            .metrics
            .store_bytes
            .set(store_bytes.min(i64::MAX as usize) as i64);
        let mut queues = Vec::with_capacity(config.shards);
        let mut workers = Vec::with_capacity(config.shards);
        for w in 0..config.shards {
            let queue = Arc::new(BoundedQueue::new(config.queue_depth));
            let policy = BatchPolicy {
                max_batch: config.max_batch,
                deadline: std::time::Duration::from_micros(config.batch_deadline_us),
            };
            let shared2 = shared.clone();
            let queue2 = queue.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sketch-worker-{w}"))
                    .spawn(move || worker::run(shared2, queue2, policy))
                    .map_err(|e| anyhow::anyhow!("spawning worker {w}: {e}"))?,
            );
            queues.push(queue);
        }
        Ok(Coordinator {
            router: Router::new(queues, config.seed),
            shared,
            workers,
            ingest: Mutex::new(ingest),
            config,
            started: Instant::now(),
        })
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.config
    }

    pub fn metrics(&self) -> &PipelineMetrics {
        &self.shared.metrics
    }

    /// This node's trace retention: the ring of completed traced
    /// queries plus the slow-query log (served over the wire by the
    /// `TraceDump` frame).
    pub fn traces(&self) -> &TraceBuf {
        &self.shared.traces
    }

    /// Complete a query's trace at the reply-write boundary: `spans`
    /// is the accumulator that rode through the worker, `write_ns` the
    /// encode+write time the caller just measured. Retention is decided
    /// by [`TraceBuf::wants`] (one atomic load on the untraced,
    /// under-threshold fast path — no lock, no allocation).
    pub fn record_trace(&self, seq: u64, spans: TraceSpans, write_ns: u64) {
        let total = spans
            .decode_ns
            .saturating_add(spans.queue_ns)
            .saturating_add(spans.scan_ns)
            .saturating_add(write_ns);
        if !self.shared.traces.wants(spans.trace_id, total) {
            return;
        }
        let (_, spec, replica, _) = self.membership();
        self.shared.traces.record(TraceRecord {
            trace_id: spans.trace_id,
            seq,
            shard: spec.map(|s| s.index).unwrap_or(0) as u32,
            replica: replica.index as u32,
            decode_ns: spans.decode_ns,
            queue_ns: spans.queue_ns,
            scan_ns: spans.scan_ns,
            write_ns,
        });
    }

    /// This node's slice of the cluster (None = owns everything).
    pub fn shard_spec(&self) -> Option<ShardSpec> {
        lock_unpoisoned(&self.shared.ownership, "ownership").spec
    }

    /// The current shard-map epoch (0 = static, unclustered map).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }

    /// The row range this node's `TopK` scans cover, clamped to the
    /// current store — what the `ShardMap` wire frame advertises.
    pub fn owned_range(&self) -> std::ops::Range<usize> {
        self.membership().3
    }

    /// One consistent `(epoch, shard spec, replica spec, owned range)`
    /// snapshot, read under a single lock acquisition — a `ShardMap`
    /// frame must never mix fields from two different adoptions.
    pub fn membership(&self) -> (u64, Option<ShardSpec>, ReplicaSpec, std::ops::Range<usize>) {
        let n = self.shared.store_n.load(Ordering::Acquire);
        let own = lock_unpoisoned(&self.shared.ownership, "ownership");
        (
            own.epoch,
            own.spec,
            own.replica,
            own.owned.start.min(n)..own.owned.end.min(n),
        )
    }

    /// Adopt a new shard identity, replica identity, and owned row
    /// range under a strictly newer epoch — the runtime half of a
    /// cluster rebalance, join/leave reconfiguration, or replica
    /// promotion (a sweep that re-slots the survivors of a shrunken
    /// replica set is just adoptions with new replica specs). The swap
    /// happens atomically under
    /// the ownership mutex; workers pick it up at their next batch,
    /// and queries stamped with the outgoing epoch still execute under
    /// the outgoing range (one level of history), so in-flight plans
    /// finish under the map they were routed with.
    #[allow(clippy::too_many_arguments)]
    pub fn adopt_shard(
        &self,
        epoch: u64,
        index: usize,
        count: usize,
        replica: ReplicaSpec,
        range: std::ops::Range<usize>,
        rows: usize,
    ) -> Result<(), AdoptError> {
        let n = self.shared.store_n.load(Ordering::Acquire);
        if rows != n {
            return Err(AdoptError::Invalid(format!(
                "adoption covers {rows} rows but this node's store has {n}"
            )));
        }
        if count == 0 || index >= count {
            return Err(AdoptError::Invalid(format!(
                "shard index {index} out of range (count {count})"
            )));
        }
        if replica.of == 0 || replica.index >= replica.of {
            return Err(AdoptError::Invalid(format!(
                "replica index {} out of range (factor {})",
                replica.index, replica.of
            )));
        }
        if range.start > range.end || range.end > n {
            return Err(AdoptError::Invalid(format!(
                "owned range {}..{} does not fit 0..{n}",
                range.start, range.end
            )));
        }
        let mut own = lock_unpoisoned(&self.shared.ownership, "ownership");
        if epoch <= own.epoch {
            return Err(AdoptError::Stale { current: own.epoch });
        }
        own.prev = Some((own.epoch, own.owned.clone()));
        own.epoch = epoch;
        own.spec = Some(ShardSpec { index, of: count });
        own.replica = replica;
        own.owned = range;
        // Mirror for lock-free admission checks; published while still
        // holding the ownership lock so the two can never disagree for
        // a reader that takes the lock.
        self.shared.epoch.store(epoch, Ordering::Release);
        self.shared.metrics.shard_adoptions.inc();
        Ok(())
    }

    /// Per-shard-worker queue depths (the `Stats` frame's per-node
    /// health section reports these for client-side balancing).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.router.depths()
    }

    /// Time since the pipeline started (per-node health).
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// The store snapshot currently serving new queries (the latest
    /// published epoch). The network layer reads `n`/`k`/`alpha` off
    /// this for its `Stats` frame.
    pub fn store(&self) -> Arc<SketchStore> {
        self.shared.snapshot()
    }

    /// Synchronous single query (round-trips one batch slot).
    pub fn query(&self, q: PairQuery) -> Result<f64> {
        Ok(self.query_batch(&[q])?[0])
    }

    /// Submit a batch of pair queries; blocks until all answers arrive.
    /// Returns distances in input order. (Convenience wrapper over
    /// [`Self::query_plan`].)
    pub fn query_batch(&self, queries: &[PairQuery]) -> Result<Vec<f64>> {
        let plan: Vec<Query> = queries.iter().map(|&q| Query::from(q)).collect();
        self.query_plan(plan)?
            .into_iter()
            .map(|r| {
                r.try_pair()
                    .ok_or_else(|| anyhow::anyhow!("pair plan produced a non-Pair reply"))
            })
            .collect()
    }

    /// The `m` nearest neighbours of row `i` (ascending distance).
    pub fn top_k(&self, i: u32, m: usize, kind: QueryKind) -> Result<Vec<(u32, f64)>> {
        match self.query_plan(vec![Query::TopK { i, m, kind }])?.pop() {
            Some(Reply::TopK(v)) => Ok(v),
            _ => unreachable!("TopK plan produced a non-TopK reply"),
        }
    }

    /// The `rows × cols` distance sub-matrix, row-major.
    pub fn block(&self, rows: Vec<u32>, cols: Vec<u32>, kind: QueryKind) -> Result<Vec<f64>> {
        match self
            .query_plan(vec![Query::Block { rows, cols, kind }])?
            .pop()
        {
            Some(Reply::Block(v)) => Ok(v),
            _ => unreachable!("Block plan produced a non-Block reply"),
        }
    }

    /// Execute a full query plan: validate, route every query to the
    /// shard workers, block until all replies arrive. Replies come back
    /// in input order, shape-matched to their queries. Each query is a
    /// routing/batching unit; a `TopK`/`Block` executes entirely on one
    /// worker under one snapshot, so its multi-value reply is
    /// epoch-consistent.
    pub fn query_plan(&self, queries: Vec<Query>) -> Result<Vec<Reply>> {
        let n = self.shared.store_n.load(Ordering::Acquire) as u32;
        for q in &queries {
            validate_query(q, n, self.shared.dtype)?;
        }
        let total = queries.len();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Reply, TraceSpans)>();
        let mut pending = 0usize;
        for (seq, query) in queries.into_iter().enumerate() {
            match self.submit_validated(
                query,
                0,
                TraceSpans::default(),
                seq,
                ReplyTo::Channel(tx.clone()),
            ) {
                Ok(()) => pending += 1,
                Err(SubmitError::Overloaded) => {
                    bail!("backpressure: shard queues full after {pending} submissions");
                }
                Err(SubmitError::Shutdown) => bail!("pipeline is shut down"),
                Err(SubmitError::WrongEpoch { current }) => {
                    bail!("wrong shard-map epoch (node is at {current})")
                }
                Err(SubmitError::Invalid(msg)) => bail!("{msg}"),
            }
        }
        drop(tx);
        let mut out: Vec<Option<Reply>> = vec![None; total];
        for _ in 0..pending {
            let (seq, reply, _spans) = rx.recv()?;
            out[seq] = Some(reply);
        }
        Ok(out
            .into_iter()
            .map(|r| r.expect("invariant: every routed query sends one reply"))
            .collect())
    }

    /// Pipelined submission: validate one query and route it, with a
    /// caller-supplied reply tag and channel. The reply arrives on
    /// `reply` as `(tag, Reply)` whenever its worker finishes — callers
    /// that interleave submission and collection (the TCP listener's
    /// per-connection pipeline) build on this; [`Self::query_plan`] is
    /// the blocking all-at-once convenience over it.
    pub fn submit(
        &self,
        query: Query,
        tag: usize,
        reply: std::sync::mpsc::Sender<(usize, Reply, TraceSpans)>,
    ) -> Result<(), SubmitError> {
        self.submit_stamped(query, 0, tag, reply)
    }

    /// [`Self::submit_traced`] with a [`CompletionQueue`] destination
    /// instead of a channel (the readiness-driven network path): the
    /// finished query lands on `queue` tagged with `conn` so the owning
    /// event loop can route it back to its connection. Identical
    /// admission semantics — same epoch check, validation, and
    /// [`SubmitError::Overloaded`] backpressure.
    pub fn submit_completion(
        &self,
        query: Query,
        epoch: u64,
        trace: TraceSpans,
        tag: usize,
        queue: &Arc<CompletionQueue>,
        conn: u64,
    ) -> Result<(), SubmitError> {
        if epoch != 0 {
            let current = self.shared.epoch.load(Ordering::Acquire);
            if epoch != current {
                return Err(SubmitError::WrongEpoch { current });
            }
        }
        let n = self.shared.store_n.load(Ordering::Acquire) as u32;
        if let Err(e) = validate_query(&query, n, self.shared.dtype) {
            return Err(SubmitError::Invalid(e.to_string()));
        }
        self.submit_validated(
            query,
            epoch,
            trace,
            tag,
            ReplyTo::Completion {
                queue: Arc::clone(queue),
                conn,
            },
        )
    }

    /// [`Self::submit`] with a shard-map epoch stamp (the v4 network
    /// path). A nonzero `epoch` that does not match this node's
    /// current one is refused with [`SubmitError::WrongEpoch`] so the
    /// caller refreshes its map instead of getting an answer routed
    /// under a map that no longer exists; `epoch == 0` (in-process
    /// callers, pre-v4 clients) is never checked.
    pub fn submit_stamped(
        &self,
        query: Query,
        epoch: u64,
        tag: usize,
        reply: std::sync::mpsc::Sender<(usize, Reply, TraceSpans)>,
    ) -> Result<(), SubmitError> {
        self.submit_traced(query, epoch, TraceSpans::default(), tag, reply)
    }

    /// [`Self::submit_stamped`] with a trace context (the v6 network
    /// path): the listener's decode span and the client's trace id ride
    /// through the worker and come back attached to the reply.
    pub fn submit_traced(
        &self,
        query: Query,
        epoch: u64,
        trace: TraceSpans,
        tag: usize,
        reply: std::sync::mpsc::Sender<(usize, Reply, TraceSpans)>,
    ) -> Result<(), SubmitError> {
        if epoch != 0 {
            let current = self.shared.epoch.load(Ordering::Acquire);
            if epoch != current {
                return Err(SubmitError::WrongEpoch { current });
            }
        }
        let n = self.shared.store_n.load(Ordering::Acquire) as u32;
        if let Err(e) = validate_query(&query, n, self.shared.dtype) {
            return Err(SubmitError::Invalid(e.to_string()));
        }
        self.submit_validated(query, epoch, trace, tag, ReplyTo::Channel(reply))
    }

    /// Route an already-validated query (shared tail of [`Self::submit`]
    /// and [`Self::query_plan`]).
    fn submit_validated(
        &self,
        query: Query,
        epoch: u64,
        trace: TraceSpans,
        tag: usize,
        reply: ReplyTo,
    ) -> Result<(), SubmitError> {
        let job = Job {
            query,
            seq: tag,
            epoch,
            trace,
            submitted: Instant::now(),
            reply,
        };
        self.shared.metrics.queries_submitted.inc();
        match self.router.route(job) {
            Ok(()) => Ok(()),
            Err(QueueError::Full(_)) => {
                self.shared.metrics.queries_rejected.inc();
                Err(SubmitError::Overloaded)
            }
            Err(QueueError::Closed) => Err(SubmitError::Shutdown),
        }
    }

    /// Apply turnstile events and publish a fresh snapshot (epoch).
    ///
    /// Refused on a sign-bits store: the streaming sketcher accumulates
    /// dense f32 projections, and silently publishing a dense snapshot
    /// over a sign store would flip the node's representation under its
    /// clients mid-connection.
    pub fn ingest(&self, events: &[StreamEvent]) -> Result<()> {
        if self.shared.dtype != SketchDtype::DenseF32 {
            bail!(
                "ingest is not supported on a {} store (the streaming \
                 sketcher is dense-only)",
                self.shared.dtype.label()
            );
        }
        let mut ingest = lock_unpoisoned(&self.ingest, "ingest");
        for &ev in events {
            ingest.apply(ev);
            self.shared.metrics.events_ingested.inc();
        }
        let snapshot = Arc::new(ingest.store().clone());
        let n = snapshot.n;
        let bytes = snapshot.memory_bytes();
        *lock_unpoisoned(&self.shared.store, "store") = snapshot;
        self.shared.store_n.store(n, Ordering::Release);
        self.shared
            .metrics
            .store_bytes
            .set(bytes.min(i64::MAX as usize) as i64);
        Ok(())
    }

    /// Graceful shutdown: drain queues, join workers.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.router.close_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Admission checks against the current snapshot size and
/// representation. Kept out of the workers so a malformed query is
/// rejected before it consumes a queue slot.
fn validate_query(q: &Query, n: u32, dtype: SketchDtype) -> Result<()> {
    match (q.kind(), dtype) {
        (QueryKind::Sign, SketchDtype::SignBits) => {}
        (QueryKind::Sign, SketchDtype::DenseF32) => {
            bail!(
                "kind sign requires a sign-bits store (this node serves {})",
                dtype.label()
            );
        }
        (kind, SketchDtype::SignBits) => {
            bail!(
                "kind {} requires a dense f32 store (this node serves {})",
                kind.label(),
                dtype.label()
            );
        }
        (_, SketchDtype::DenseF32) => {}
    }
    match q {
        Query::Pair { i, j, .. } => {
            if *i >= n || *j >= n {
                bail!("query ({i}, {j}) out of range (n={n})");
            }
        }
        Query::TopK { i, m, .. } => {
            if *i >= n {
                bail!("topk row {i} out of range (n={n})");
            }
            if *m == 0 {
                bail!("topk m must be >= 1");
            }
        }
        Query::Block { rows, cols, .. } => {
            if rows.is_empty() || cols.is_empty() {
                bail!("block query must name at least one row and one column");
            }
            let cells = rows.len().saturating_mul(cols.len());
            if cells > MAX_BLOCK_CELLS {
                bail!(
                    "block of {}x{} = {cells} cells exceeds the per-query limit of \
                     {MAX_BLOCK_CELLS}; split it into smaller blocks",
                    rows.len(),
                    cols.len()
                );
            }
            if let Some(bad) = rows.iter().chain(cols).find(|&&r| r >= n) {
                bail!("block index {bad} out of range (n={n})");
            }
        }
    }
    Ok(())
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.router.close_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The one-level ownership history: unstamped and current-epoch
    /// queries resolve to the live range, the retained previous epoch
    /// to its old range, and anything older resolves to *nothing* —
    /// the worker refuses rather than answering under a range the
    /// query was never routed with.
    #[test]
    fn ownership_range_resolution_honours_one_level_of_history() {
        let own = Ownership {
            epoch: 5,
            spec: Some(ShardSpec { index: 1, of: 3 }),
            replica: ReplicaSpec { index: 1, of: 2 },
            owned: 20..40,
            prev: Some((4, 10..30)),
        };
        assert_eq!(own.range_for(0), Some(20..40), "unstamped is never checked");
        assert_eq!(own.range_for(5), Some(20..40), "current epoch, current range");
        assert_eq!(own.range_for(4), Some(10..30), "previous epoch, retained range");
        assert_eq!(own.range_for(3), None, "older than the history: refuse");
        assert_eq!(own.range_for(6), None, "from the future: refuse");

        let fresh = Ownership {
            epoch: 1,
            spec: None,
            replica: ReplicaSpec::solo(),
            owned: 0..usize::MAX,
            prev: None,
        };
        assert_eq!(fresh.range_for(0), Some(0..usize::MAX));
        assert_eq!(fresh.range_for(1), Some(0..usize::MAX));
        assert_eq!(fresh.range_for(2), None);
    }

    #[test]
    fn replica_spec_parses_like_shard_spec() {
        assert_eq!(ReplicaSpec::parse("0/1"), Some(ReplicaSpec { index: 0, of: 1 }));
        assert_eq!(ReplicaSpec::parse(" 1 / 2 "), Some(ReplicaSpec { index: 1, of: 2 }));
        assert_eq!(ReplicaSpec::parse("2/2"), None, "index must be < of");
        assert_eq!(ReplicaSpec::parse("0/0"), None, "factor must be >= 1");
        assert_eq!(ReplicaSpec::parse("1"), None);
        assert_eq!(ReplicaSpec::parse("a/b"), None);
        assert_eq!(ReplicaSpec::solo(), ReplicaSpec::default());
        assert_eq!(format!("{}", ReplicaSpec { index: 1, of: 3 }), "1/3");
    }
}
