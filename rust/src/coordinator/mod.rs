//! The L3 coordinator: a sharded, batching, backpressured serving
//! pipeline over the sketch store.
//!
//! Topology:
//!
//! ```text
//!           ┌──────────── ClientHandle (clone-able) ───────────┐
//!           │ router: power-of-two-choices over shard queues   │
//!           └──────┬───────────────┬───────────────┬───────────┘
//!   bounded queue  ▼               ▼               ▼   (backpressure:
//!            [ shard 0 ]     [ shard 1 ]     [ shard 2 ]  reject when full)
//!            worker thread   worker thread   worker thread
//!            dynamic batcher (size + deadline), estimator hot path
//!                  ▲ read-mostly Arc<SketchStore> snapshots
//!  ingest thread ──┘ turnstile events → new snapshot per epoch
//! ```
//!
//! Distances are estimated with the optimal quantile estimator by
//! default (select + one pow — the paper's point is that this is cheap
//! enough to sit on a serving hot path); gm/fp/median are available
//! per-query for comparison workloads.

mod backpressure;
mod batcher;
mod router;
mod shard;
mod worker;

pub use backpressure::{BoundedQueue, QueueError};
pub use batcher::{BatchPolicy, Batcher};
pub use router::Router;
pub use shard::ShardSet;

use crate::estimators::{
    FractionalPower, GeometricMean, OptimalQuantile, QuantileEstimator, ScaleEstimator,
};
use crate::metrics::PipelineMetrics;
use crate::sketch::{SketchStore, StreamEvent, StreamingSketcher};
use crate::util::config::PipelineConfig;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which estimator serves a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryKind {
    /// Optimal quantile (default; the paper's contribution).
    Oq,
    /// Geometric mean (k pow baseline).
    Gm,
    /// Fractional power.
    Fp,
    /// Sample median (Indyk baseline).
    Median,
}

/// One distance query.
#[derive(Debug, Clone, Copy)]
pub struct PairQuery {
    pub i: u32,
    pub j: u32,
    pub kind: QueryKind,
}

pub(crate) struct Job {
    pub query: PairQuery,
    pub seq: usize,
    pub submitted: Instant,
    pub reply: std::sync::mpsc::Sender<(usize, f64)>,
}

/// Everything a worker needs, shared.
pub(crate) struct Shared {
    pub store: Mutex<Arc<SketchStore>>, // swapped by ingest epochs
    pub oq: OptimalQuantile,
    pub gm: GeometricMean,
    pub fp: FractionalPower,
    pub median: QuantileEstimator,
    pub metrics: PipelineMetrics,
    pub stop: AtomicBool,
}

impl Shared {
    pub fn snapshot(&self) -> Arc<SketchStore> {
        self.store.lock().unwrap().clone()
    }

    #[inline]
    pub fn estimate(&self, kind: QueryKind, buf: &mut [f64]) -> f64 {
        match kind {
            QueryKind::Oq => self.oq.estimate(buf),
            QueryKind::Gm => self.gm.estimate(buf),
            QueryKind::Fp => self.fp.estimate(buf),
            QueryKind::Median => self.median.estimate(buf),
        }
    }
}

/// The running pipeline.
pub struct Coordinator {
    shared: Arc<Shared>,
    router: Router,
    workers: Vec<std::thread::JoinHandle<()>>,
    ingest: Mutex<StreamingSketcher>,
    config: PipelineConfig,
}

impl Coordinator {
    /// Start workers over an existing sketch store.
    pub fn start(config: PipelineConfig, store: SketchStore) -> Result<Coordinator> {
        if store.k != config.k {
            bail!("store k={} != config k={}", store.k, config.k);
        }
        let alpha = config.alpha;
        let k = config.k;
        let n = store.n;
        let ingest = StreamingSketcher::new(alpha, config.dim, k, config.seed, n);
        let shared = Arc::new(Shared {
            store: Mutex::new(Arc::new(store)),
            oq: OptimalQuantile::new(alpha, k),
            gm: GeometricMean::new(alpha, k),
            fp: FractionalPower::new(alpha, k),
            median: QuantileEstimator::median(alpha, k),
            metrics: PipelineMetrics::default(),
            stop: AtomicBool::new(false),
        });
        let mut queues = Vec::with_capacity(config.shards);
        let mut workers = Vec::with_capacity(config.shards);
        for w in 0..config.shards {
            let queue = Arc::new(BoundedQueue::new(config.queue_depth));
            let policy = BatchPolicy {
                max_batch: config.max_batch,
                deadline: std::time::Duration::from_micros(config.batch_deadline_us),
            };
            let shared2 = shared.clone();
            let queue2 = queue.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sketch-worker-{w}"))
                    .spawn(move || worker::run(shared2, queue2, policy))
                    .expect("spawning worker"),
            );
            queues.push(queue);
        }
        Ok(Coordinator {
            router: Router::new(queues, config.seed),
            shared,
            workers,
            ingest: Mutex::new(ingest),
            config,
        })
    }

    pub fn config(&self) -> &PipelineConfig {
        &self.config
    }

    pub fn metrics(&self) -> &PipelineMetrics {
        &self.shared.metrics
    }

    /// Synchronous single query (round-trips one batch slot).
    pub fn query(&self, q: PairQuery) -> Result<f64> {
        Ok(self.query_batch(&[q])?[0])
    }

    /// Submit a batch; blocks until all answers arrive. Returns answers
    /// in input order.
    pub fn query_batch(&self, queries: &[PairQuery]) -> Result<Vec<f64>> {
        let n = {
            let snap = self.shared.snapshot();
            snap.n as u32
        };
        for q in queries {
            if q.i >= n || q.j >= n {
                bail!("query ({}, {}) out of range (n={n})", q.i, q.j);
            }
        }
        let (tx, rx) = std::sync::mpsc::channel::<(usize, f64)>();
        let mut pending = 0usize;
        for (seq, &query) in queries.iter().enumerate() {
            let job = Job {
                query,
                seq,
                submitted: Instant::now(),
                reply: tx.clone(),
            };
            self.shared.metrics.queries_submitted.inc();
            match self.router.route(job) {
                Ok(()) => pending += 1,
                Err(QueueError::Full(_)) => {
                    self.shared.metrics.queries_rejected.inc();
                    bail!("backpressure: shard queues full after {pending} submissions");
                }
                Err(QueueError::Closed) => bail!("pipeline is shut down"),
            }
        }
        drop(tx);
        let mut out = vec![f64::NAN; queries.len()];
        for _ in 0..pending {
            let (seq, val) = rx.recv()?;
            out[seq] = val;
        }
        Ok(out)
    }

    /// Apply turnstile events and publish a fresh snapshot (epoch).
    pub fn ingest(&self, events: &[StreamEvent]) -> Result<()> {
        let mut ingest = self.ingest.lock().unwrap();
        for &ev in events {
            ingest.apply(ev);
            self.shared.metrics.events_ingested.inc();
        }
        let snapshot = Arc::new(ingest.store().clone());
        *self.shared.store.lock().unwrap() = snapshot;
        Ok(())
    }

    /// Graceful shutdown: drain queues, join workers.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.router.close_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.router.close_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
