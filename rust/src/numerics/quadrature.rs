//! Quadrature: fixed-order Gauss–Legendre panels and an adaptive
//! subdivision driver.
//!
//! The stable pdf/cdf integrands (Nolan/Zolotarev representation) are
//! smooth but can concentrate sharply near one endpoint; adaptive
//! bisection with a 15-point GL rule handles both regimes.

/// 15-point Gauss–Legendre nodes/weights on [-1, 1].
const GL15_X: [f64; 15] = [
    -0.987_992_518_020_485_4,
    -0.937_273_392_400_705_9,
    -0.848_206_583_410_427_2,
    -0.724_417_731_360_170_1,
    -0.570_972_172_608_538_9,
    -0.394_151_347_077_563_4,
    -0.201_194_093_997_434_5,
    0.0,
    0.201_194_093_997_434_5,
    0.394_151_347_077_563_4,
    0.570_972_172_608_538_9,
    0.724_417_731_360_170_1,
    0.848_206_583_410_427_2,
    0.937_273_392_400_705_9,
    0.987_992_518_020_485_4,
];
const GL15_W: [f64; 15] = [
    0.030_753_241_996_117_3,
    0.070_366_047_488_108_1,
    0.107_159_220_467_171_9,
    0.139_570_677_926_154_3,
    0.166_269_205_816_993_9,
    0.186_161_000_015_562_2,
    0.198_431_485_327_111_6,
    0.202_578_241_925_561_3,
    0.198_431_485_327_111_6,
    0.186_161_000_015_562_2,
    0.166_269_205_816_993_9,
    0.139_570_677_926_154_3,
    0.107_159_220_467_171_9,
    0.070_366_047_488_108_1,
    0.030_753_241_996_117_3,
];

/// Fixed 15-point Gauss–Legendre on [a, b].
pub fn gl15<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64) -> f64 {
    let c = 0.5 * (a + b);
    let h = 0.5 * (b - a);
    let mut acc = 0.0;
    for i in 0..15 {
        acc += GL15_W[i] * f(c + h * GL15_X[i]);
    }
    acc * h
}

/// Adaptive quadrature: recursively bisect until the GL15 estimates of
/// the halves agree with the parent to `tol` (absolute + relative mix).
///
/// `max_depth` bounds the recursion; the worst leaves are still summed so
/// the result degrades gracefully instead of hanging.
pub fn adaptive<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, tol: f64) -> f64 {
    adaptive_impl(f, a, b, gl15(f, a, b), tol, 24)
}

fn adaptive_impl<F: Fn(f64) -> f64>(
    f: &F,
    a: f64,
    b: f64,
    whole: f64,
    tol: f64,
    depth: u32,
) -> f64 {
    let m = 0.5 * (a + b);
    let left = gl15(f, a, m);
    let right = gl15(f, m, b);
    let err = (left + right - whole).abs();
    if depth == 0 || err <= tol * (1.0 + (left + right).abs()) {
        return left + right;
    }
    adaptive_impl(f, a, m, left, tol * 0.7, depth - 1)
        + adaptive_impl(f, m, b, right, tol * 0.7, depth - 1)
}

/// Integrate a decaying oscillatory-ish integrand over [0, ∞) by fixed
/// geometric panels: [0,1], [1,2], [2,4], ... stopping when a panel's
/// contribution is below `tol` relative to the running total (with a
/// 3-panel patience so zero-crossing panels don't stop it early).
pub fn semi_infinite<F: Fn(f64) -> f64>(f: &F, tol: f64) -> f64 {
    let mut total = adaptive(f, 0.0, 1.0, tol);
    let mut lo = 1.0;
    let mut hi = 2.0;
    let mut quiet = 0;
    for _ in 0..64 {
        let part = adaptive(f, lo, hi, tol);
        total += part;
        if part.abs() <= tol * (1.0 + total.abs()) {
            quiet += 1;
            if quiet >= 3 {
                break;
            }
        } else {
            quiet = 0;
        }
        lo = hi;
        hi *= 2.0;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn gl15_polynomial_exact() {
        // GL15 integrates polynomials of degree <= 29 exactly.
        let f = |x: f64| 3.0 * x * x;
        assert!((gl15(&f, 0.0, 2.0) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn adaptive_handles_endpoint_spike() {
        // ∫_0^1 1/sqrt(x) dx = 2, integrable singularity at 0.
        let f = |x: f64| if x > 0.0 { 1.0 / x.sqrt() } else { 0.0 };
        let got = adaptive(&f, 1e-12, 1.0, 1e-10);
        assert!((got - 2.0).abs() < 1e-5, "got {got}");
    }

    #[test]
    fn adaptive_smooth() {
        let got = adaptive(&|x: f64| x.sin(), 0.0, PI, 1e-12);
        assert!((got - 2.0).abs() < 1e-10, "got {got}");
    }

    #[test]
    fn semi_infinite_gaussian() {
        // ∫_0^∞ e^{-t^2} dt = sqrt(pi)/2
        let got = semi_infinite(&|t: f64| (-t * t).exp(), 1e-12);
        assert!((got - PI.sqrt() / 2.0).abs() < 1e-9, "got {got}");
    }

    #[test]
    fn semi_infinite_oscillatory_decay() {
        // ∫_0^∞ cos(t) e^{-t} dt = 1/2
        let got = semi_infinite(&|t: f64| t.cos() * (-t).exp(), 1e-12);
        assert!((got - 0.5).abs() < 1e-9, "got {got}");
    }
}
