//! Numerical substrate: special functions, RNG, quadrature, root finding,
//! scalar optimization and compensated summation.
//!
//! Everything in this module is dependency-free (the build environment is
//! offline; no `rand`/`statrs`/`libm` crates) and validated against closed
//! forms in unit tests.

pub mod kahan;
pub mod optimize;
pub mod quadrature;
pub mod rng;
pub mod roots;
pub mod specfun;

pub use kahan::KahanSum;
pub use rng::{Rng, SplitMix64, Xoshiro256pp};
