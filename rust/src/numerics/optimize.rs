//! Scalar minimization: golden-section search with a parabolic
//! refinement pass. Used for q*(α) (Eq. 6) and the fractional-power λ*
//! (Li–Hastie) objective.

const INV_PHI: f64 = 0.618_033_988_749_894_9; // (sqrt(5)-1)/2

/// Minimize unimodal `f` on [a, b] by golden-section search; returns
/// (argmin, min).
pub fn golden_section<F: Fn(f64) -> f64>(f: &F, mut a: f64, mut b: f64, tol: f64) -> (f64, f64) {
    assert!(a < b, "golden_section: need a < b");
    let mut c = b - INV_PHI * (b - a);
    let mut d = a + INV_PHI * (b - a);
    let mut fc = f(c);
    let mut fd = f(d);
    while (b - a).abs() > tol {
        if fc < fd {
            b = d;
            d = c;
            fd = fc;
            c = b - INV_PHI * (b - a);
            fc = f(c);
        } else {
            a = c;
            c = d;
            fc = fd;
            d = a + INV_PHI * (b - a);
            fd = f(d);
        }
    }
    let x = 0.5 * (a + b);
    (x, f(x))
}

/// Minimize over a coarse grid first (robust to multimodality from
/// numerical noise), then refine the best cell with golden section.
pub fn grid_then_golden<F: Fn(f64) -> f64>(
    f: &F,
    a: f64,
    b: f64,
    grid: usize,
    tol: f64,
) -> (f64, f64) {
    assert!(grid >= 3);
    let h = (b - a) / grid as f64;
    let mut best_i = 0usize;
    let mut best_v = f64::INFINITY;
    for i in 0..=grid {
        let x = a + h * i as f64;
        let v = f(x);
        if v < best_v {
            best_v = v;
            best_i = i;
        }
    }
    let lo = a + h * best_i.saturating_sub(1) as f64;
    let hi = (a + h * (best_i + 1) as f64).min(b);
    golden_section(f, lo, hi, tol)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_quadratic() {
        let (x, v) = golden_section(&|x: f64| (x - 1.3).powi(2) + 2.0, -5.0, 5.0, 1e-10);
        // Minimization can't localize beyond ~sqrt(machine-eps)·scale:
        // near the optimum f varies by less than one ulp of f(x*).
        assert!((x - 1.3).abs() < 1e-6, "x={x}");
        assert!((v - 2.0).abs() < 1e-12);
    }

    #[test]
    fn grid_refine_handles_flat_edges() {
        // Minimum interior to [0,1] with flat-ish tails.
        let f = |x: f64| -(-((x - 0.203) * 8.0).powi(2)).exp();
        let (x, _) = grid_then_golden(&f, 0.001, 0.999, 64, 1e-9);
        assert!((x - 0.203).abs() < 1e-6, "x={x}");
    }
}
