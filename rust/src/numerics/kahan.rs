//! Compensated (Kahan–Neumaier) summation.
//!
//! Monte-Carlo drivers accumulate 10^5..10^7 terms; naive f64 summation
//! loses ~sqrt(n)·eps relative accuracy which is visible in the bias tables
//! (Fig 3) where the signal itself is O(1e-3). Neumaier's variant also
//! handles the case where the addend is larger than the running sum.

/// Running compensated sum.
#[derive(Debug, Clone, Copy, Default)]
pub struct KahanSum {
    sum: f64,
    comp: f64,
    count: u64,
}

impl KahanSum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one term.
    #[inline]
    pub fn add(&mut self, x: f64) {
        let t = self.sum + x;
        if self.sum.abs() >= x.abs() {
            self.comp += (self.sum - t) + x;
        } else {
            self.comp += (x - t) + self.sum;
        }
        self.sum = t;
        self.count += 1;
    }

    /// Compensated total.
    #[inline]
    pub fn total(&self) -> f64 {
        self.sum + self.comp
    }

    /// Number of terms added.
    #[inline]
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of the added terms (NaN when empty).
    #[inline]
    pub fn mean(&self) -> f64 {
        self.total() / self.count as f64
    }
}

/// Compensated sum of a slice.
pub fn sum(xs: &[f64]) -> f64 {
    let mut acc = KahanSum::new();
    for &x in xs {
        acc.add(x);
    }
    acc.total()
}

/// Online mean/variance (Welford) with compensated mean updates.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunningMoments {
    n: u64,
    mean: f64,
    m2: f64,
}

impl RunningMoments {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (divides by n).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance (divides by n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        (self.sample_variance() / self.n as f64).sqrt()
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &RunningMoments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kahan_beats_naive_on_adversarial_sum() {
        // 1 + 1e-16 * 1e6: naive f64 drops every small term.
        let mut k = KahanSum::new();
        k.add(1.0);
        for _ in 0..1_000_000 {
            k.add(1e-16);
        }
        let expect = 1.0 + 1e-10;
        assert!((k.total() - expect).abs() < 1e-14, "got {}", k.total());
    }

    #[test]
    fn running_moments_match_two_pass() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.3).collect();
        let mut rm = RunningMoments::new();
        for &x in &xs {
            rm.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((rm.mean() - mean).abs() < 1e-10);
        assert!((rm.variance() - var).abs() < 1e-8);
    }

    #[test]
    fn moments_merge_equals_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut a = RunningMoments::new();
        let mut b = RunningMoments::new();
        for &x in &xs[..200] {
            a.add(x);
        }
        for &x in &xs[200..] {
            b.add(x);
        }
        let mut whole = RunningMoments::new();
        for &x in &xs {
            whole.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }
}
