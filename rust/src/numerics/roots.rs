//! Root finding: Brent's method on a bracketing interval, plus a bracket
//! grower. Used for stable quantiles F^{-1}(p) and the bias-table
//! inversions.

/// Find x in [a, b] with f(x) = 0 via Brent's method. `f(a)` and `f(b)`
/// must have opposite signs.
pub fn brent<F: Fn(f64) -> f64>(f: &F, mut a: f64, mut b: f64, tol: f64, max_iter: u32) -> f64 {
    let mut fa = f(a);
    let mut fb = f(b);
    assert!(
        fa * fb <= 0.0,
        "brent: not a bracket: f({a})={fa}, f({b})={fb}"
    );
    if fa.abs() < fb.abs() {
        std::mem::swap(&mut a, &mut b);
        std::mem::swap(&mut fa, &mut fb);
    }
    let mut c = a;
    let mut fc = fa;
    let mut d = b - a;
    let mut mflag = true;
    for _ in 0..max_iter {
        if fb == 0.0 || (b - a).abs() < tol {
            return b;
        }
        let mut s = if fa != fc && fb != fc {
            // Inverse quadratic interpolation.
            a * fb * fc / ((fa - fb) * (fa - fc))
                + b * fa * fc / ((fb - fa) * (fb - fc))
                + c * fa * fb / ((fc - fa) * (fc - fb))
        } else {
            // Secant.
            b - fb * (b - a) / (fb - fa)
        };
        let cond = {
            let lo = (3.0 * a + b) / 4.0;
            let (lo, hi) = if lo < b { (lo, b) } else { (b, lo) };
            !(lo < s && s < hi)
                || (mflag && (s - b).abs() >= (b - c).abs() / 2.0)
                || (!mflag && (s - b).abs() >= (c - d).abs() / 2.0)
                || (mflag && (b - c).abs() < tol)
                || (!mflag && (c - d).abs() < tol)
        };
        if cond {
            s = 0.5 * (a + b);
            mflag = true;
        } else {
            mflag = false;
        }
        let fs = f(s);
        d = c;
        c = b;
        fc = fb;
        if fa * fs < 0.0 {
            b = s;
            fb = fs;
        } else {
            a = s;
            fa = fs;
        }
        if fa.abs() < fb.abs() {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut fa, &mut fb);
        }
    }
    b
}

/// Grow a bracket for a monotone-increasing-ish `f` around an initial
/// guess until sign change is found; returns (lo, hi).
pub fn grow_bracket<F: Fn(f64) -> f64>(f: &F, x0: f64, step0: f64) -> (f64, f64) {
    let f0 = f(x0);
    if f0 == 0.0 {
        return (x0, x0);
    }
    let mut step = step0.abs().max(1e-12);
    // Search in the direction that should reduce |f| for increasing f.
    let dir = if f0 < 0.0 { 1.0 } else { -1.0 };
    let mut prev = x0;
    let mut x = x0;
    for _ in 0..200 {
        x += dir * step;
        let fx = f(x);
        if fx == 0.0 {
            return (x, x);
        }
        if fx * f0 < 0.0 {
            return if prev < x { (prev, x) } else { (x, prev) };
        }
        prev = x;
        step *= 2.0;
    }
    panic!("grow_bracket: no sign change found from x0={x0}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn brent_cubic() {
        let f = |x: f64| x * x * x - 2.0;
        let r = brent(&f, 0.0, 2.0, 1e-14, 200);
        assert!((r - 2f64.powf(1.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn brent_transcendental() {
        let f = |x: f64| x.cos() - x;
        let r = brent(&f, 0.0, 1.0, 1e-14, 200);
        assert!((f(r)).abs() < 1e-12);
    }

    #[test]
    fn bracket_then_solve() {
        let f = |x: f64| x.exp() - 10.0;
        let (lo, hi) = grow_bracket(&f, 0.0, 0.5);
        let r = brent(&f, lo, hi, 1e-13, 200);
        assert!((r - 10f64.ln()).abs() < 1e-10);
    }
}
