//! Seedable, counter-splittable PRNGs.
//!
//! The sketch matrix `R ∈ R^{D×k}` is never materialized globally: every
//! entry r_{ij} must be *re-derivable* from `(seed, i, j)` so that
//! streaming turnstile updates (paper §1.3) can regenerate the needed row
//! on the fly in one pass. `SplitMix64` provides the stateless
//! counter-hash; `Xoshiro256pp` provides the fast sequential stream for
//! Monte-Carlo work.

/// Trait for the minimal RNG surface the library needs.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in the *open* interval (0, 1) — safe for log/tan transforms.
    #[inline]
    fn uniform_open(&mut self) -> f64 {
        loop {
            let u = self.uniform();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform in (lo, hi).
    #[inline]
    fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform_open()
    }

    /// Exp(1) via inversion.
    #[inline]
    fn exponential(&mut self) -> f64 {
        -self.uniform_open().ln()
    }

    /// Standard normal via Box–Muller (no cached spare: keeps the trait
    /// object-safe and the streams reproducible regardless of call mix).
    #[inline]
    fn normal(&mut self) -> f64 {
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    #[inline]
    fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection.
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }
}

/// SplitMix64: stateless-hashable; `SplitMix64::hash(seed, ctr)` is the
/// counter-based generator used for sketch matrix entries.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// One finalization round: a high-quality 64-bit mix of `x`.
    #[inline]
    pub fn mix(mut x: u64) -> u64 {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^ (x >> 31)
    }

    /// Stateless counter hash: independent 64-bit value per (seed, ctr).
    #[inline]
    pub fn hash(seed: u64, ctr: u64) -> u64 {
        Self::mix(seed ^ Self::mix(ctr))
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main sequential generator (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream for a labelled subtask (worker id,
    /// column block, ...): equivalent to seeding from `hash(seed,label)`.
    pub fn substream(seed: u64, label: u64) -> Self {
        Self::new(SplitMix64::hash(seed, label))
    }

    /// The 2^128 jump polynomial: advances the state as if 2^128 calls to
    /// next_u64 were made. Used to hand non-overlapping subsequences to
    /// worker threads.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180e_c6d3_3cfd_0aba,
            0xd5a6_1266_f0c9_392c,
            0xa958_2618_e03f_c9aa,
            0x39ab_dc45_29b1_661c,
        ];
        let mut t = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    t[0] ^= self.s[0];
                    t[1] ^= self.s[1];
                    t[2] ^= self.s[2];
                    t[3] ^= self.s[3];
                }
                let _ = self.next_u64();
            }
        }
        self.s = t;
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xoshiro_reference_vector() {
        // First outputs for seed state {1,2,3,4} per the reference
        // implementation of xoshiro256++.
        let mut g = Xoshiro256pp { s: [1, 2, 3, 4] };
        let got: Vec<u64> = (0..4).map(|_| g.next_u64()).collect();
        assert_eq!(got, vec![41943041, 58720359, 3588806011781223, 3591011842654386]);
    }

    #[test]
    fn splitmix_hash_is_deterministic_and_spread() {
        let a = SplitMix64::hash(42, 7);
        let b = SplitMix64::hash(42, 7);
        let c = SplitMix64::hash(42, 8);
        let d = SplitMix64::hash(43, 7);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut g = Xoshiro256pp::new(7);
        let mut acc = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let u = g.uniform();
            assert!((0.0..1.0).contains(&u));
            acc += u;
        }
        let mean = acc / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256pp::new(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = g.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn exponential_mean() {
        let mut g = Xoshiro256pp::new(13);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| g.exponential()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut g = Xoshiro256pp::new(17);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[g.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts {counts:?}");
        }
    }

    #[test]
    fn jump_produces_disjoint_streams() {
        let mut a = Xoshiro256pp::new(23);
        let mut b = a.clone();
        b.jump();
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
