//! Special functions: log-gamma, gamma, erf/erfc, and the sin-product
//! helpers that the estimator coefficient formulas use.
//!
//! The gm / hm / fp estimator coefficients are products of Γ(·) and
//! sin(·) terms evaluated at arguments like α/k that approach poles of Γ;
//! everything here works in log space where possible and is validated
//! against high-precision references in the tests.

use std::f64::consts::PI;

/// Lanczos approximation coefficients (g = 7, n = 9), |rel err| < 1e-14
/// over the right half plane.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.999_999_999_999_809_93,
    676.520_368_121_885_1,
    -1_259.139_216_722_402_8,
    771.323_428_777_653_13,
    -176.615_029_162_140_6,
    12.507_343_278_686_905,
    -0.138_571_095_265_720_12,
    9.984_369_578_019_571_6e-6,
    1.505_632_735_149_311_6e-7,
];

/// Natural log of |Γ(x)| for any non-pole real x.
///
/// For x <= 0.5 uses the reflection formula
/// `Γ(x)Γ(1−x) = π / sin(πx)` (in log space).
pub fn lgamma(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    if x <= 0.0 && x == x.floor() {
        return f64::INFINITY; // pole
    }
    if x < 0.5 {
        // log|Γ(x)| = log(π) − log|sin(πx)| − log|Γ(1−x)|
        return PI.ln() - sin_pi(x).abs().ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = LANCZOS[0];
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + LANCZOS_G + 0.5;
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// Γ(x) with correct sign for negative non-integer arguments.
pub fn gamma(x: f64) -> f64 {
    if x <= 0.0 && x == x.floor() {
        return f64::NAN; // pole
    }
    let sign = if x > 0.0 {
        1.0
    } else {
        // Sign of Γ(x) for x<0 alternates per unit interval:
        // Γ < 0 on (-1,0), > 0 on (-2,-1), ...
        if (x.floor() as i64).rem_euclid(2) == 1 {
            -1.0
        } else {
            1.0
        }
    };
    sign * lgamma(x).exp()
}

/// sin(πx) computed with argument reduction to keep accuracy for large
/// or near-integer x.
pub fn sin_pi(x: f64) -> f64 {
    let r = x - 2.0 * (x / 2.0).floor(); // r in [0,2)
    (PI * r).sin()
}

/// cos(πx) with argument reduction.
pub fn cos_pi(x: f64) -> f64 {
    let r = x - 2.0 * (x / 2.0).floor();
    (PI * r).cos()
}

/// Error function, Abramowitz–Stegun 7.1.26-style rational approximation
/// refined by one Newton step against erfc's continued fraction; |err| <
/// 1.2e-7 from the base formula, < 1e-12 after refinement via series for
/// |x| < 3.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x < 3.0 {
        // Series: erf(x) = 2/sqrt(pi) * sum (-1)^n x^(2n+1) / (n! (2n+1))
        // converges fast for x < 3 (worst case ~40 terms).
        let mut term = x;
        let mut sum = x;
        let x2 = x * x;
        let mut n = 0usize;
        while term.abs() > 1e-17 * sum.abs() && n < 200 {
            n += 1;
            term *= -x2 / n as f64;
            sum += term / (2 * n + 1) as f64;
        }
        (2.0 / PI.sqrt()) * sum
    } else {
        1.0 - erfc_large(x)
    }
}

/// Complement erfc for x >= 3 via the asymptotic continued fraction.
fn erfc_large(x: f64) -> f64 {
    // Asymptotic expansion: erfc(x) = exp(-x^2)/(x sqrt(pi)) * (1 - 1/(2x^2) + 3/(4x^4) - ...)
    let x2 = x * x;
    let mut s = 1.0;
    let mut term = 1.0;
    for n in 1..12 {
        term *= -((2 * n - 1) as f64) / (2.0 * x2);
        s += term;
    }
    (-x2).exp() / (x * PI.sqrt()) * s
}

/// erfc(x) = 1 - erf(x).
pub fn erfc(x: f64) -> f64 {
    if x >= 3.0 {
        erfc_large(x)
    } else if x <= -3.0 {
        2.0 - erfc_large(-x)
    } else {
        1.0 - erf(x)
    }
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Standard normal quantile (Acklam's rational approximation + one
/// Newton refinement step; |rel err| < 1e-12 on (1e-300, 1-1e-16)).
pub fn norm_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_quantile domain: p in (0,1), got {p}");
    // Acklam coefficients.
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_690e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    let p_low = 0.02425;
    let x = if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Newton step: x -= (Phi(x)-p)/phi(x).
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// log of the absolute t-th moment of |S(α,1)| (standard symmetric
/// α-stable, characteristic function e^{−|t|^α}):
///
///   E|x|^t = (2/π) Γ(1 − t/α) Γ(t) sin(πt/2),  valid for −1 < t < α, t ≠ 0.
///
/// Returns the *value* (not log): the formula is a product of terms that
/// can individually blow up near t→0 but the product is smooth; evaluated
/// via lgamma in log space with explicit sign tracking.
pub fn stable_abs_moment(alpha: f64, t: f64) -> f64 {
    assert!(
        t > -1.0 && t < alpha && t != 0.0,
        "stable_abs_moment domain: -1 < t < alpha, t != 0 (alpha={alpha}, t={t})"
    );
    // Γ(t) sin(πt/2): `gamma` carries the correct sign for t < 0 and the
    // apparent singularities cancel in the product (Γ(t) ~ 1/t as t→0
    // against sin(πt/2) ~ πt/2 stays finite in f64 down to |t| ~ 1e-300).
    let gs = gamma(t) * sin_pi(t / 2.0);
    (2.0 / PI) * lgamma(1.0 - t / alpha).exp() * gs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!(
            (a - b).abs() <= tol * (1.0 + b.abs()),
            "expected {b}, got {a} (tol {tol})"
        );
    }

    #[test]
    fn lgamma_known_values() {
        close(lgamma(1.0), 0.0, 1e-13);
        close(lgamma(2.0), 0.0, 1e-13);
        close(lgamma(0.5), (PI.sqrt()).ln(), 1e-13);
        close(lgamma(5.0), 24.0f64.ln(), 1e-13);
        close(lgamma(10.5), 13.940_625_219_403_763, 1e-12); // ref: math.lgamma
    }

    #[test]
    fn gamma_negative_arguments() {
        // Γ(-0.5) = -2√π ; Γ(-1.5) = 4√π/3
        close(gamma(-0.5), -2.0 * PI.sqrt(), 1e-12);
        close(gamma(-1.5), 4.0 * PI.sqrt() / 3.0, 1e-12);
        assert!(gamma(-1.0).is_nan());
    }

    #[test]
    fn gamma_recurrence_holds() {
        for &x in &[0.1, 0.37, 1.9, 3.25, 7.5, -0.3, -1.7] {
            close(gamma(x + 1.0), x * gamma(x), 1e-11);
        }
    }

    #[test]
    fn erf_known_values() {
        close(erf(0.0), 0.0, 1e-15);
        close(erf(1.0), 0.842_700_792_949_714_9, 1e-12);
        close(erf(2.0), 0.995_322_265_018_952_7, 1e-12);
        close(erf(-1.0), -0.842_700_792_949_714_9, 1e-12);
        close(erfc(3.5), 7.430_983_723_414_128e-7, 1e-9);
    }

    #[test]
    fn norm_quantile_inverts_cdf() {
        for &p in &[1e-8, 1e-4, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0 - 1e-6] {
            let x = norm_quantile(p);
            close(norm_cdf(x), p, 1e-10);
        }
    }

    #[test]
    fn stable_moment_gaussian_case() {
        // alpha=2: |x| with x ~ S(2,1) has cf e^{-t^2} => x ~ N(0, 2).
        // E|x|^t = 2^{t/2} * E|z|^t with z std normal; E|z|^t =
        // 2^{t/2} Γ((t+1)/2)/√π.
        for &t in &[0.5, 1.0, 1.5, -0.5] {
            let expect = 2.0f64.powf(t) * (lgamma((t + 1.0) / 2.0).exp()) / PI.sqrt();
            close(stable_abs_moment(2.0, t), expect, 1e-10);
        }
    }

    #[test]
    fn stable_moment_cauchy_case() {
        // alpha=1 (Cauchy, scale 1): E|x|^t = 1/cos(πt/2) for |t|<1.
        for &t in &[0.3, 0.6, -0.4, -0.8] {
            close(stable_abs_moment(1.0, t), 1.0 / cos_pi(t / 2.0), 1e-10);
        }
    }

    #[test]
    fn sin_cos_pi_reduction() {
        close(sin_pi(0.5), 1.0, 1e-15);
        close(sin_pi(1.0), 0.0, 1e-12);
        close(sin_pi(1e6 + 0.25), (PI * 0.25).sin(), 1e-9);
        close(cos_pi(1.0), -1.0, 1e-15);
    }
}
