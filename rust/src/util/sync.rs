//! Synchronization helpers for the hot-path modules.
//!
//! pallas-lint (PL005) bans bare `unwrap()` in the admission path:
//! every mutex there either documents its contract inline with
//! `expect("invariant: …")` or routes through [`lock_unpoisoned`],
//! which panics with the same `invariant:`-prefixed message shape.

// Enforced by pallas-lint (PL002) and re-stated to the compiler: this
// module must stay free of unsafe code.
#![forbid(unsafe_code)]

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex whose critical sections cannot panic, which makes
/// poisoning unreachable. `what` names the mutex so the panic message
/// states exactly which contract broke.
pub fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>, what: &str) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(_) => panic!("invariant: {what} mutex is never poisoned"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_the_guard_on_clean_locks() {
        let m = Mutex::new(7u32);
        *lock_unpoisoned(&m, "test") += 1;
        assert_eq!(*lock_unpoisoned(&m, "test"), 8);
    }

    #[test]
    fn names_the_mutex_when_poisoned() {
        let m = Mutex::new(0u32);
        let m = std::sync::Arc::new(m);
        let mc = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = mc.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let caught = std::panic::catch_unwind(|| {
            let _g = lock_unpoisoned(&m, "completion");
        })
        .unwrap_err();
        let msg = caught.downcast_ref::<String>().expect("string payload");
        assert_eq!(msg, "invariant: completion mutex is never poisoned");
    }
}
