//! Tiny CLI argument parser (no clap offline): subcommand + `--flag`,
//! `--key value` pairs, with typed accessors and a usage printer.

use std::collections::BTreeMap;

/// Parsed command line: `prog subcommand [--k v | --flag] [positional..]`.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("missing required option --{0}")]
    Missing(String),
    #[error("option --{0} has invalid value '{1}': expected {2}")]
    Invalid(String, String, &'static str),
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn req(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::Missing(name.into()))
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError::Invalid(name.into(), s.into(), "float")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError::Invalid(name.into(), s.into(), "integer")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError::Invalid(name.into(), s.into(), "integer")),
        }
    }

    /// Comma-separated f64 list, e.g. `--alphas 0.5,1,1.5`.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| CliError::Invalid(name.into(), s.into(), "float list"))
                })
                .collect(),
        }
    }

    /// Comma-separated usize list.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| CliError::Invalid(name.into(), s.into(), "integer list"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_opts_flags() {
        let a = parse("serve --port 8080 --verbose --alpha=1.5 input.json");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0).unwrap(), 8080);
        assert!(a.flag("verbose"));
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 1.5);
        assert_eq!(a.positional, vec!["input.json"]);
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("bench --alphas 0.5,1.0,2 --ks 10,50");
        assert_eq!(a.f64_list_or("alphas", &[]).unwrap(), vec![0.5, 1.0, 2.0]);
        assert_eq!(a.usize_list_or("ks", &[]).unwrap(), vec![10, 50]);
        assert_eq!(a.f64_or("missing", 7.5).unwrap(), 7.5);
    }

    #[test]
    fn errors_are_typed() {
        let a = parse("x --n abc");
        assert!(matches!(a.usize_or("n", 1), Err(CliError::Invalid(..))));
        assert!(matches!(a.req("absent"), Err(CliError::Missing(_))));
    }
}
