//! Pipeline configuration: a typed view over a JSON config file plus
//! CLI overrides. This is what `stablesketch serve` / the examples load.

use super::cli::Args;
use super::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Full pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// The l_α index (0 < α ≤ 2).
    pub alpha: f64,
    /// Number of projections (sketch width).
    pub k: usize,
    /// Original dimensionality D.
    pub dim: usize,
    /// RNG seed for the projection matrix R (entries are re-derivable
    /// from (seed, i, j) — see `numerics::rng`).
    pub seed: u64,
    /// Number of shard workers.
    pub shards: usize,
    /// Max queries per batch (dynamic batcher).
    pub max_batch: usize,
    /// Batch deadline in microseconds.
    pub batch_deadline_us: u64,
    /// Bounded queue depth per shard (backpressure).
    pub queue_depth: usize,
    /// In-node threads for one worker's TopK/Block scan (0 = auto:
    /// min(4, available cores); 1 = always sequential). Results are
    /// bit-identical at every setting — the parallel merge preserves
    /// `(distance, row)` order exactly.
    pub scan_threads: usize,
    /// Use the PJRT artifact path for projections when available.
    pub use_pjrt: bool,
    /// Directory of AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            alpha: 1.0,
            k: 64,
            dim: 4096,
            seed: 0x57AB1E_u64,
            shards: 2,
            max_batch: 64,
            batch_deadline_us: 200,
            queue_depth: 1024,
            scan_threads: 0,
            use_pjrt: false,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl PipelineConfig {
    /// Load from a JSON file; unknown keys are rejected (typo safety).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        let Json::Obj(map) = v else {
            bail!("config root must be an object");
        };
        for (key, val) in map {
            match key.as_str() {
                "alpha" => cfg.alpha = val.as_f64().context("alpha: number")?,
                "k" => cfg.k = val.as_usize().context("k: integer")?,
                "dim" => cfg.dim = val.as_usize().context("dim: integer")?,
                "seed" => cfg.seed = val.as_f64().context("seed: number")? as u64,
                "shards" => cfg.shards = val.as_usize().context("shards: integer")?,
                "max_batch" => cfg.max_batch = val.as_usize().context("max_batch: integer")?,
                "batch_deadline_us" => {
                    cfg.batch_deadline_us = val.as_f64().context("batch_deadline_us")? as u64
                }
                "queue_depth" => {
                    cfg.queue_depth = val.as_usize().context("queue_depth: integer")?
                }
                "scan_threads" => {
                    cfg.scan_threads = val.as_usize().context("scan_threads: integer")?
                }
                "use_pjrt" => cfg.use_pjrt = val.as_bool().context("use_pjrt: bool")?,
                "artifacts_dir" => {
                    cfg.artifacts_dir = val.as_str().context("artifacts_dir: string")?.into()
                }
                other => bail!("unknown config key: {other}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides (--alpha, --k, --dim, --seed, --shards ...).
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        self.alpha = args.f64_or("alpha", self.alpha)?;
        self.k = args.usize_or("k", self.k)?;
        self.dim = args.usize_or("dim", self.dim)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.shards = args.usize_or("shards", self.shards)?;
        self.max_batch = args.usize_or("max-batch", self.max_batch)?;
        self.queue_depth = args.usize_or("queue-depth", self.queue_depth)?;
        self.scan_threads = args.usize_or("scan-threads", self.scan_threads)?;
        if args.flag("pjrt") {
            self.use_pjrt = true;
        }
        if let Some(dir) = args.get("artifacts-dir") {
            self.artifacts_dir = dir.to_string();
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.alpha > 0.0 && self.alpha <= 2.0) {
            bail!("alpha must be in (0, 2], got {}", self.alpha);
        }
        if self.k < 2 {
            bail!("k must be >= 2, got {}", self.k);
        }
        if self.dim == 0 || self.shards == 0 || self.max_batch == 0 || self.queue_depth == 0 {
            bail!("dim/shards/max_batch/queue_depth must be positive");
        }
        if self.scan_threads > 256 {
            bail!("scan_threads must be <= 256 (0 = auto), got {}", self.scan_threads);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("alpha", Json::num(self.alpha)),
            ("k", Json::num(self.k as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("batch_deadline_us", Json::num(self.batch_deadline_us as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("scan_threads", Json::num(self.scan_threads as f64)),
            ("use_pjrt", Json::Bool(self.use_pjrt)),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_json() {
        let cfg = PipelineConfig {
            alpha: 1.5,
            k: 128,
            ..Default::default()
        };
        let v = cfg.to_json();
        let back = PipelineConfig::from_json(&v).unwrap();
        assert_eq!(back.alpha, 1.5);
        assert_eq!(back.k, 128);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let bad = Json::parse(r#"{"alhpa": 1.0}"#).unwrap();
        assert!(PipelineConfig::from_json(&bad).is_err());
        let bad2 = Json::parse(r#"{"alpha": 3.0}"#).unwrap();
        assert!(PipelineConfig::from_json(&bad2).is_err());
        let bad3 = Json::parse(r#"{"k": 1}"#).unwrap();
        assert!(PipelineConfig::from_json(&bad3).is_err());
    }

    #[test]
    fn cli_overrides() {
        let args = crate::util::cli::Args::parse(
            "serve --alpha 0.5 --k 32".split_whitespace().map(String::from),
        );
        let cfg = PipelineConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.alpha, 0.5);
        assert_eq!(cfg.k, 32);
    }
}
