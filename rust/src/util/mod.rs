//! Runtime-free utility substrates (the offline build has no serde /
//! clap): a JSON parser + writer, a CLI argument parser, and config
//! loading.

pub mod cli;
pub mod config;
pub mod json;
pub mod sync;

pub use json::Json;
