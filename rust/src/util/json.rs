//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! the python AOT step), pipeline configs, and bench result rows. Not a
//! general-purpose library: no streaming, documents are small (< MBs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — bench outputs get diffed across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy).
                    write!(f, "null")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our documents).
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\ny"));
        // Re-parse our own output.
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn deterministic_output_order() {
        let v = Json::obj(vec![("z", Json::num(1.0)), ("a", Json::num(2.0))]);
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }
}
