//! Mini property-based testing framework (proptest is unavailable
//! offline): seeded generators + a runner that reports the failing case
//! and re-runs it with a shrunk variant where possible.

use crate::numerics::{Rng, Xoshiro256pp};

/// A value generator.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Xoshiro256pp) -> T;
}

impl<T, F: Fn(&mut Xoshiro256pp) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Xoshiro256pp) -> T {
        self(rng)
    }
}

/// Uniform f64 in [lo, hi].
pub fn f64_in(lo: f64, hi: f64) -> impl Gen<f64> {
    move |rng: &mut Xoshiro256pp| rng.uniform_in(lo, hi)
}

/// usize in [lo, hi].
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
    move |rng: &mut Xoshiro256pp| lo + rng.below((hi - lo + 1) as u64) as usize
}

/// α in the paper's domain, avoiding the extreme endpoint.
pub fn alpha_gen() -> impl Gen<f64> {
    move |rng: &mut Xoshiro256pp| {
        // Mix of a uniform draw and the paper's special points.
        match rng.below(5) {
            0 => 1.0,
            1 => 2.0,
            2 => 0.5,
            _ => (rng.uniform_in(0.1, 2.0) * 100.0).round() / 100.0,
        }
    }
}

/// Vec of f64 samples from a heavy-tailed distribution (Cauchy — worst
/// case for numerics).
pub fn heavy_vec(len: usize) -> impl Gen<Vec<f64>> {
    move |rng: &mut Xoshiro256pp| {
        (0..len)
            .map(|_| (std::f64::consts::PI * (rng.uniform_open() - 0.5)).tan())
            .collect()
    }
}

/// Property runner: `cases` seeded cases; on failure panics with the
/// case index and seed so it can be replayed exactly.
pub fn check<T, G, P>(name: &str, cases: usize, gen: G, mut prop: P)
where
    G: Gen<T>,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let base = 0xBADC0DEu64 ^ (name.len() as u64).wrapping_mul(0x9E3779B97F4A7C15);
    for case in 0..cases {
        let mut rng = Xoshiro256pp::substream(base, case as u64);
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case} (seed base {base:#x}):\n  \
                 value: {value:?}\n  reason: {msg}"
            );
        }
    }
}

/// Two-value property runner.
pub fn check2<A, B, GA, GB, P>(name: &str, cases: usize, ga: GA, gb: GB, mut prop: P)
where
    GA: Gen<A>,
    GB: Gen<B>,
    P: FnMut(&A, &B) -> Result<(), String>,
    A: std::fmt::Debug,
    B: std::fmt::Debug,
{
    let base = 0xBADC0DEu64 ^ (name.len() as u64).wrapping_mul(0x2545F4914F6CDD1D);
    for case in 0..cases {
        let mut rng = Xoshiro256pp::substream(base, case as u64);
        let a = ga.generate(&mut rng);
        let b = gb.generate(&mut rng);
        if let Err(msg) = prop(&a, &b) {
            panic!(
                "property '{name}' failed at case {case}:\n  a: {a:?}\n  b: {b:?}\n  \
                 reason: {msg}"
            );
        }
    }
}

/// Assertion helper for relative closeness.
pub fn assert_rel(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + b.abs()) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (rel tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_is_deterministic() {
        let mut seen = Vec::new();
        check("collect", 5, f64_in(0.0, 1.0), |v| {
            seen.push(*v);
            Ok(())
        });
        let mut seen2 = Vec::new();
        check("collect", 5, f64_in(0.0, 1.0), |v| {
            seen2.push(*v);
            Ok(())
        });
        assert_eq!(seen, seen2);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failures_panic_with_case_info() {
        check("fails", 10, usize_in(0, 100), |&v| {
            if v < 1000 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }
}
