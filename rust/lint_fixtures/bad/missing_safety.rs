// Planted violation: the block below dereferences a raw pointer with
// no safety comment above it (PL001), in a file that is not in the
// audited allowlist (PL002).

pub fn read_first(xs: &[u8]) -> u8 {
    unsafe { *xs.as_ptr() }
}
