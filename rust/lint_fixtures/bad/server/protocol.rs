// Planted PL004 violations for the version-gate registry rule:
// `TAG_ROGUE` is declared but missing from the registry, and
// `TAG_FUTURE` is registered as since-v4 but the decoder below never
// gates it behind `if version < …`.

pub const MIN_PROTOCOL_VERSION: u8 = 1;
pub const PROTOCOL_VERSION: u8 = 4;
const EPOCH_SINCE_VERSION: u8 = 4;

const TAG_PING: u8 = 0x01;
const TAG_ROGUE: u8 = 0x02;
const TAG_FUTURE: u8 = 0x03;

pub const FRAME_TAG_MIN_VERSION: &[(u8, u8)] = &[
    (TAG_PING, MIN_PROTOCOL_VERSION),
    (TAG_FUTURE, EPOCH_SINCE_VERSION),
];

pub fn decode(version: u8, tag: u8) -> Result<u8, u8> {
    if version < MIN_PROTOCOL_VERSION {
        return Err(version);
    }
    match tag {
        TAG_PING => Ok(tag),
        TAG_ROGUE => Ok(tag),
        TAG_FUTURE => Ok(tag),
        other => Err(other),
    }
}
