// Planted PL005 violations in a hot-path module: one bare unwrap, one
// empty expect, one expect whose message does not document the
// violated contract. The last two calls show the accepted forms.

use std::sync::Mutex;

pub fn drain_depths(q: &Mutex<Vec<u32>>) -> usize {
    let a = q.lock().unwrap().len();
    let b = q.lock().expect("").len();
    let c = q.lock().expect("queue lock").len();
    let d = q
        .lock()
        .expect("invariant: depth mutex is never poisoned")
        .len();
    let e = q.lock().map(|g| g.len()).unwrap_or(0);
    a + b + c + d + e
}
