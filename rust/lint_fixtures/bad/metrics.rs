// Planted PL006 violations: a duplicated stat key, a non-snake_case
// key, and a key with no matching Prometheus exposition family.

pub struct Snapshot {
    submitted: u64,
    orphaned: u64,
}

impl Snapshot {
    pub fn stat_entries(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("queries_submitted", self.submitted),
            ("queries_submitted", self.submitted),
            ("BadKey", 7),
            ("orphan_metric", self.orphaned),
        ]
    }

    pub fn metrics_text(&self) -> String {
        let family = "stablesketch_queries_submitted_total";
        format!("{family} {}\n", self.submitted)
    }
}
