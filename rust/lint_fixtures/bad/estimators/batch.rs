// Planted PL003 violations: clock reads inside a kernel hot-loop
// module. Spans are measured at stage boundaries by the coordinator,
// never inside the fill/select inner loops.

pub fn fill_timed(out: &mut [f32], a: &[f32], b: &[f32]) -> u128 {
    let start = std::time::Instant::now();
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = (x - y).abs();
    }
    start.elapsed().as_nanos()
}
