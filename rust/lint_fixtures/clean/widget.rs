//! A violation-free fixture: ordinary safe code that every rule must
//! pass untouched.

pub fn widget_sum(xs: &[u64]) -> u64 {
    xs.iter().copied().sum()
}

pub fn widget_max(xs: &[u64]) -> Option<u64> {
    xs.iter().copied().max()
}

#[cfg(test)]
mod tests {
    #[test]
    fn sums() {
        assert_eq!(super::widget_sum(&[1, 2]), 3);
    }
}
