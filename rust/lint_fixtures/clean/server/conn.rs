// A hot-path module (by path suffix) whose unwraps all live inside a
// `#[cfg(test)]` block: test code exercises panics on purpose and is
// exempt from every rule, so this file must lint clean.

pub fn admissible(inflight: usize, cap: usize) -> bool {
    inflight < cap
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    #[test]
    fn unwraps_freely_in_tests() {
        let q = Mutex::new(vec![1u32]);
        assert_eq!(q.lock().unwrap().len(), 1);
        assert_eq!(q.lock().expect("held").len(), 1);
        assert!(super::admissible(0, 1));
    }
}
