//! Fig 6 — empirical k·MSE of gm / fp / oq,c vs k, at α ∈ {0.5, 1,
//! 1.5, 2}, plus the gm exact curve (closed form) and the oq asymptote.
//!
//! Paper shape: for α > 1 and k ≥ 20 the oq estimator's MSE is below
//! both gm and fp (fp degrades badly near α = 2); for α < 1 fp wins.
//! Paper used 10⁷ replicates; default here is 10⁵ per cell (REPS= to
//! override), which separates the curves far beyond their error bars.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::estimators::*;
use stablesketch::simul::mc::{run_estimator, McConfig};
use stablesketch::util::json::Json;

fn main() {
    let reps = common::reps(100_000);
    let alphas = [0.5f64, 1.0, 1.5, 2.0];
    let ks = [10usize, 20, 30, 50, 75, 100];
    println!("== Fig 6: k·MSE (reps={reps}/cell; lower = better) ==");
    let mut rows = Vec::new();
    for &alpha in &alphas {
        println!("\n-- alpha = {alpha} --");
        let mut table = Table::new(&["k", "gm", "gm-exact", "fp", "oq,c", "oq-asymptote"]);
        for &k in &ks {
            let cfg = McConfig {
                reps,
                seed: 0xF16 ^ ((alpha * 100.0) as u64) << 8 ^ k as u64,
                d_true: 1.0,
            };
            let gm = GeometricMean::new(alpha, k);
            let fp = FractionalPower::new(alpha, k);
            let oq = OptimalQuantile::new(alpha, k);
            let s_gm = run_estimator(&gm, &cfg);
            let s_fp = run_estimator(&fp, &cfg);
            let s_oq = run_estimator(&oq, &cfg);
            let gm_exact = gm.exact_variance_factor() * k as f64;
            let oq_asym = oq.asymptotic_variance_factor();
            table.row(vec![
                format!("{k}"),
                format!("{:.3}", s_gm.k_mse_normalized),
                format!("{gm_exact:.3}"),
                format!("{:.3}", s_fp.k_mse_normalized),
                format!("{:.3}", s_oq.k_mse_normalized),
                format!("{oq_asym:.3}"),
            ]);
            rows.push(Json::obj(vec![
                ("alpha", Json::num(alpha)),
                ("k", Json::num(k as f64)),
                ("k_mse_gm", Json::num(s_gm.k_mse_normalized)),
                ("k_mse_gm_exact", Json::num(gm_exact)),
                ("k_mse_fp", Json::num(s_fp.k_mse_normalized)),
                ("k_mse_oq", Json::num(s_oq.k_mse_normalized)),
                ("oq_asymptote", Json::num(oq_asym)),
                ("reps", Json::num(reps as f64)),
            ]));
        }
        table.print();
    }
    common::dump("fig6_mse.json", &rows);

    // Paper-shape assertions.
    let cell = |a: f64, k: usize, key: &str| {
        rows.iter()
            .find(|r| {
                r.get("alpha").unwrap().as_f64() == Some(a)
                    && r.get("k").unwrap().as_f64() == Some(k as f64)
            })
            .unwrap()
            .get(key)
            .unwrap()
            .as_f64()
            .unwrap()
    };
    // α > 1, k ≥ 20: oq beats gm (§4.1).
    for &a in &[1.5, 2.0] {
        for &k in &[20usize, 50, 100] {
            assert!(
                cell(a, k, "k_mse_oq") < cell(a, k, "k_mse_gm"),
                "oq !< gm at alpha={a}, k={k}"
            );
        }
    }
    // oq beats fp in MSE at α = 1.5 (k ≥ 20). NOTE at exactly α = 2 the
    // projected samples are Gaussian — no heavy tail exists — and fp with
    // λ* → 1/2 degenerates to a (near-optimal) arithmetic-mean-like
    // estimator, so it wins on *MSE* there; the paper's complaint about
    // fp near α = 2 is about its TAIL (no exponential bounds, moments
    // barely above order 2 for α < 2) — reproduced in fig7_tails.
    for &k in &[20usize, 50, 100] {
        assert!(
            cell(1.5, k, "k_mse_oq") < cell(1.5, k, "k_mse_fp"),
            "oq !< fp at alpha=1.5, k={k}"
        );
    }
    // α < 1: fp is the best of the three (§4.1).
    assert!(cell(0.5, 50, "k_mse_fp") < cell(0.5, 50, "k_mse_oq"));
    // gm MC matches its closed form.
    let (mc, exact) = (cell(1.0, 50, "k_mse_gm"), cell(1.0, 50, "k_mse_gm_exact"));
    assert!((mc / exact - 1.0).abs() < 0.1, "gm MC {mc} vs exact {exact}");
    println!(
        "\nshape checks passed: oq < gm for α>1 & k≥20; oq < fp at α=1.5; \
         fp wins at α=0.5; gm MC = closed form"
    );
}
