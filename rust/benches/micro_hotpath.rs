//! Micro-benchmarks of the hot-path primitives (the §Perf ledger):
//! selection vs fractional powers at the operation level, the naive vs
//! optimized selector ablation, the fused abs-diff-select kernel vs the
//! copy-then-estimate scalar path, sampling, and projection throughput.

mod common;

use stablesketch::bench_util::{bench, black_box, BenchConfig, Table};
use stablesketch::estimators::quickselect::{select_kth, select_kth_f32, select_kth_naive};
use stablesketch::estimators::{BatchScratch, FusedDiffEstimator, OptimalQuantile, ScaleEstimator};
use stablesketch::numerics::{Rng, Xoshiro256pp};
use stablesketch::sketch::{SketchEngine, SketchStore};
use stablesketch::stable::StableSampler;
use stablesketch::util::json::Json;

fn main() {
    let cfg = BenchConfig {
        warmup_batches: 2,
        samples: 11,
        iters_per_batch: 0,
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut table = Table::new(&["op", "ns/op", "note"]);
    let push = |name: &str, ns: f64, note: &str, rows: &mut Vec<Json>, table: &mut Table| {
        table.row(vec![name.into(), format!("{ns:.1}"), note.into()]);
        rows.push(Json::obj(vec![
            ("op", Json::str(name)),
            ("ns", Json::num(ns)),
        ]));
    };

    let mut rng = Xoshiro256pp::new(1);

    // --- scalar primitives -----------------------------------------
    let xs: Vec<f64> = (0..1024).map(|_| rng.normal().abs() + 0.01).collect();
    let mut i = 0usize;
    let m = bench("powf", &cfg, || {
        i = (i + 1) & 1023;
        black_box(xs[i].powf(0.0123))
    });
    push("powf(x, α/k)", m.ns_per_op_median, "the gm/fp per-sample op", &mut rows, &mut table);

    let m = bench("abs+cmp", &cfg, || {
        i = (i + 1) & 1023;
        black_box(xs[i].abs() < 1.0)
    });
    push("abs+cmp", m.ns_per_op_median, "the oq per-sample op", &mut rows, &mut table);

    // --- selection at several k ------------------------------------
    for &k in &[50usize, 200, 1000] {
        let pool: Vec<Vec<f64>> = (0..32)
            .map(|_| (0..k).map(|_| rng.normal()).collect())
            .collect();
        let mut buf = vec![0.0; k];
        let mut c = 0usize;
        let m_opt = bench("select", &cfg, || {
            c = (c + 1) & 31;
            buf.copy_from_slice(&pool[c]);
            black_box(select_kth(&mut buf, k / 2))
        });
        push(
            &format!("select_kth k={k}"),
            m_opt.ns_per_op_median,
            "production selector",
            &mut rows,
            &mut table,
        );
        // The chunked branchless f32 kernel against the f64 Hoare
        // reference above (same selection, half the element width, no
        // data-dependent branches in the partition pass).
        let pool32: Vec<Vec<f32>> = pool
            .iter()
            .map(|v| v.iter().map(|&x| x as f32).collect())
            .collect();
        let mut buf32 = vec![0.0f32; k];
        let m_chunked = bench("select_f32", &cfg, || {
            c = (c + 1) & 31;
            buf32.copy_from_slice(&pool32[c]);
            black_box(select_kth_f32(&mut buf32, k / 2))
        });
        push(
            &format!("select_kth_f32 k={k}"),
            m_chunked.ns_per_op_median,
            &format!(
                "chunked branchless kernel — {:.1}x vs f64 Hoare",
                m_opt.ns_per_op_median / m_chunked.ns_per_op_median
            ),
            &mut rows,
            &mut table,
        );
        let m_naive = bench("select_naive", &cfg, || {
            c = (c + 1) & 31;
            black_box(select_kth_naive(&pool[c], k / 2))
        });
        push(
            &format!("select_naive k={k}"),
            m_naive.ns_per_op_median,
            "paper's allocating recursion",
            &mut rows,
            &mut table,
        );
        // pow loop for the same k (what gm does per estimate)
        let m_pow = bench("powloop", &cfg, || {
            let mut p = 1.0f64;
            for &x in &pool[c] {
                p *= x.abs().powf(0.01);
            }
            black_box(p)
        });
        push(
            &format!("k-pow loop k={k}"),
            m_pow.ns_per_op_median,
            "gm hot path",
            &mut rows,
            &mut table,
        );
    }

    // --- fused abs-diff-select vs copy-then-estimate ----------------
    // The serving hot path before this refactor: copy the f32 sketch
    // diff into an f64 buffer (reused across the batch, as the old
    // worker loop did — allocation is deliberately NOT timed), then
    // estimate. The fused kernel selects straight over the f32
    // differences in a reused scratch.
    let mut fused_speedup_k256 = 0.0;
    let mut fused_speedup_k1000 = 0.0;
    for &k in &[64usize, 256, 1000] {
        let alpha = 1.0;
        let est = OptimalQuantile::new(alpha, k);
        let mut store = SketchStore::zeros(2, k, alpha, 0);
        for i in 0..2 {
            for v in store.row_mut(i).iter_mut() {
                *v = rng.normal() as f32;
            }
        }
        let mut buf = vec![0.0f64; k];
        let m_scalar = bench("copy+estimate", &cfg, || {
            store.diff_into(0, 1, &mut buf);
            black_box(est.estimate(&mut buf))
        });
        push(
            &format!("pair copy+estimate k={k}"),
            m_scalar.ns_per_op_median,
            "scalar path: f64 copy into a reused buffer",
            &mut rows,
            &mut table,
        );
        let mut scratch = BatchScratch::new(k);
        let m_fused = bench("fused", &cfg, || {
            black_box(est.estimate_diff(store.row(0), store.row(1), &mut scratch))
        });
        let speedup = m_scalar.ns_per_op_median / m_fused.ns_per_op_median;
        push(
            &format!("pair fused abs-diff-select k={k}"),
            m_fused.ns_per_op_median,
            &format!("f32 select, zero copy — {speedup:.1}x vs scalar"),
            &mut rows,
            &mut table,
        );
        if k == 256 {
            fused_speedup_k256 = speedup;
        }
        if k == 1000 {
            fused_speedup_k1000 = speedup;
        }
    }

    // --- one worker's TopK scan: sequential vs fanned out ------------
    // The in-node scoped-thread fan-out (scan_threads); both sides are
    // bit-identical by construction (tests/kernel_equivalence.rs), so
    // this measures pure wall-clock. The speedup is recorded, not
    // asserted — CI boxes may be single-core.
    {
        let (n, k) = (12_000usize, 64usize);
        let est = OptimalQuantile::new(1.0, k);
        let mut store = SketchStore::zeros(n, k, 1.0, 5);
        for i in 0..n {
            for v in store.row_mut(i).iter_mut() {
                *v = rng.normal() as f32;
            }
        }
        let scan_cfg = BenchConfig {
            warmup_batches: 1,
            samples: 7,
            iters_per_batch: 1,
        };
        let mut scratch = BatchScratch::new(k);
        let m_seq = bench("scan_seq", &scan_cfg, || {
            black_box(store.top_m_scan(&est, 0, 0..n, 10, 1, &mut scratch))
        });
        push(
            &format!("topk scan seq n={n}"),
            m_seq.ns_per_op_median,
            "one worker, one thread",
            &mut rows,
            &mut table,
        );
        let m_par = bench("scan_par", &scan_cfg, || {
            black_box(store.top_m_scan(&est, 0, 0..n, 10, 4, &mut scratch))
        });
        push(
            &format!("topk scan par n={n}"),
            m_par.ns_per_op_median,
            &format!(
                "scoped-thread fan-out — {:.1}x vs sequential",
                m_seq.ns_per_op_median / m_par.ns_per_op_median
            ),
            &mut rows,
            &mut table,
        );
    }

    // --- sampling ---------------------------------------------------
    for &alpha in &[0.5f64, 1.0, 2.0] {
        let s = StableSampler::new(alpha);
        let m = bench("cms", &cfg, || black_box(s.sample(&mut rng)));
        push(
            &format!("CMS sample α={alpha}"),
            m.ns_per_op_median,
            "sketch-matrix entry",
            &mut rows,
            &mut table,
        );
    }

    // --- projection -------------------------------------------------
    let (dim, k) = (2048usize, 64usize);
    let engine = SketchEngine::new(1.0, dim, k, 3);
    let mut u = vec![0.0f32; dim];
    for d in (0..dim).step_by(17) {
        u[d] = (d % 13) as f32 * 0.1 - 0.5;
    }
    let mut out = vec![0.0f32; k];
    let m = bench("project_row", &cfg, || {
        engine.project_row(&u, &mut out);
        black_box(out[0])
    });
    let nnz = u.iter().filter(|&&x| x != 0.0).count();
    push(
        &format!("project_row D={dim} nnz={nnz} k={k}"),
        m.ns_per_op_median,
        &format!("{:.2} ns/(nnz·k)", m.ns_per_op_median / (nnz * k) as f64),
        &mut rows,
        &mut table,
    );

    table.print();
    common::dump("micro_hotpath.json", &rows);

    // Shape: the fused kernel must beat the copy-then-estimate scalar
    // path at serving width (expected ~2x+ from halved memory traffic
    // plus the removed per-query allocation).
    println!("\nfused vs scalar at k=256: {fused_speedup_k256:.1}x");
    println!("fused vs scalar at k=1000: {fused_speedup_k1000:.1}x");
    assert!(
        fused_speedup_k256 > 1.0,
        "fused path slower than copy+estimate at k=256 ({fused_speedup_k256:.2}x)"
    );
    assert!(
        fused_speedup_k1000 > 1.0,
        "fused path slower than copy+estimate at k=1000 ({fused_speedup_k1000:.2}x)"
    );
}
