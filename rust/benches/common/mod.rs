#![allow(dead_code)]

//! Shared helpers for the figure benches.

use stablesketch::util::json::Json;

/// Replicates, overridable via `REPS=` env (CI runs smaller).
pub fn reps(default: usize) -> usize {
    std::env::var("REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Standard α grid used across figures.
pub fn alpha_grid(step: f64) -> Vec<f64> {
    let mut v = Vec::new();
    let mut a = step;
    while a <= 2.0 + 1e-9 {
        v.push((a * 100.0).round() / 100.0);
        a += step;
    }
    v
}

pub fn dump(file: &str, rows: &[Json]) {
    match stablesketch::bench_util::write_rows(file, rows) {
        Ok(path) => eprintln!("[rows written to {}]", path.display()),
        Err(e) => eprintln!("[warn: could not write bench rows: {e}]"),
    }
}
