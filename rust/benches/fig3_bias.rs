//! Fig 3 — the bias correction factor B_{α,k} = E(d̂_(α),oq; d = 1).
//!
//! Paper shape: B > 1 (almost everywhere), large at small k (e.g.
//! B_{0.1,10} ≈ 1.24), decaying toward 1 as k grows, with stair-step
//! wiggle from the order-statistic index. The checked-in table
//! (tables_data.rs) is printed and then *independently revalidated* by a
//! fresh Monte-Carlo run with a different seed.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::estimators::tables;
use stablesketch::util::json::Json;

fn main() {
    let reps = common::reps(100_000);
    let alphas = [0.1, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0];
    let ks = [10usize, 15, 20, 30, 50, 100, 200, 500];
    println!("== Fig 3: bias correction B_(α,k) (table | fresh MC, reps={reps}) ==");
    let mut table = Table::new(&[
        "alpha", "k=10", "k=15", "k=20", "k=30", "k=50", "k=100", "k=200", "k=500",
    ]);
    let mut rows = Vec::new();
    for &alpha in &alphas {
        let mut cells = vec![format!("{alpha:.2}")];
        for &k in &ks {
            let b_table = tables::bias_correction(alpha, k);
            let b_fresh = tables::simulate_bias(alpha, k, reps, 0xFEED ^ k as u64);
            cells.push(format!("{b_table:.3}|{b_fresh:.3}"));
            rows.push(Json::obj(vec![
                ("alpha", Json::num(alpha)),
                ("k", Json::num(k as f64)),
                ("b_table", Json::num(b_table)),
                ("b_fresh_mc", Json::num(b_fresh)),
            ]));
            // Cross-validation: two independent MC estimates must agree.
            assert!(
                (b_table - b_fresh).abs() < 0.05 * b_table,
                "alpha={alpha} k={k}: table {b_table} vs fresh {b_fresh}"
            );
        }
        table.row(cells);
    }
    table.print();
    common::dump("fig3_bias.json", &rows);

    // Paper shape: B large at small k, ≈1 at k=500.
    let b_small = tables::bias_correction(0.1, 10);
    let b_large = tables::bias_correction(0.1, 500);
    assert!(b_small > 1.15, "B(0.1,10) = {b_small}");
    assert!((b_large - 1.0).abs() < 0.02, "B(0.1,500) = {b_large}");
    println!("\nshape checks passed: B(0.1,10)={b_small:.3} (paper ≈1.24), B(0.1,500)={b_large:.3}");
}
