//! System bench (E2E row in EXPERIMENTS.md): end-to-end pipeline
//! throughput/latency across shard counts, batch sizes, and estimator
//! kinds, on a synthetic heavy-tailed corpus — all through the query
//! plan API.
//!
//! This is the serving claim behind the paper's "reducing training time
//! from one week to one day": per-distance cost is dominated by the
//! estimator, so the oq estimator's cheap fused hot path shows up
//! directly in queries/second. The TopK section additionally shows the
//! plan-level win: one `Query::TopK` amortizes snapshot + scratch over
//! all n−1 candidates vs. issuing n−1 pair queries.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::coordinator::{Coordinator, PairQuery, Query, QueryKind, Reply};
use stablesketch::numerics::{Rng, Xoshiro256pp};
use stablesketch::sketch::SketchEngine;
use stablesketch::simul::{Corpus, CorpusConfig};
use stablesketch::util::config::PipelineConfig;
use stablesketch::util::json::Json;
use std::time::Instant;

fn run_workload(
    coord: &Coordinator,
    n: usize,
    queries: usize,
    kind: QueryKind,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Xoshiro256pp::new(seed);
    let t0 = Instant::now();
    let mut done = 0usize;
    while done < queries {
        let burst = (queries - done).min(512);
        let plan: Vec<Query> = (0..burst)
            .map(|_| Query::Pair {
                i: rng.below(n as u64) as u32,
                j: rng.below(n as u64) as u32,
                kind,
            })
            .collect();
        coord.query_plan(plan).expect("plan");
        done += burst;
    }
    let dt = t0.elapsed().as_secs_f64();
    let qps = queries as f64 / dt;
    let p99 = coord.metrics().query_latency.quantile_ns(0.99) as f64 / 1e3;
    (qps, p99)
}

/// TopK via the plan API vs. the same kNN answered with n−1 pair
/// queries per anchor: returns (plan distances/s, pairs distances/s).
fn run_topk_comparison(coord: &Coordinator, n: usize, anchors: usize, m: usize) -> (f64, f64) {
    let scanned_before = coord.metrics().topk_candidates_scanned.get();
    let t0 = Instant::now();
    let plan: Vec<Query> = (0..anchors)
        .map(|a| Query::TopK {
            i: (a % n) as u32,
            m,
            kind: QueryKind::Oq,
        })
        .collect();
    let replies = coord.query_plan(plan).expect("topk plan");
    let plan_dt = t0.elapsed().as_secs_f64();
    for r in &replies {
        let Reply::TopK(v) = r else { panic!("non-topk reply") };
        assert_eq!(v.len(), m.min(n - 1));
    }
    let scanned = coord.metrics().topk_candidates_scanned.get() - scanned_before;
    assert_eq!(scanned as usize, anchors * (n - 1), "scan counter drifted");

    let t0 = Instant::now();
    for a in 0..anchors {
        let i = (a % n) as u32;
        let pairs: Vec<PairQuery> = (0..n as u32)
            .filter(|&j| j != i)
            .map(|j| PairQuery {
                i,
                j,
                kind: QueryKind::Oq,
            })
            .collect();
        coord.query_batch(&pairs).expect("pair batch");
    }
    let pairs_dt = t0.elapsed().as_secs_f64();
    let distances = (anchors * (n - 1)) as f64;
    (distances / plan_dt, distances / pairs_dt)
}

fn main() {
    let queries = common::reps(60_000);
    let (n, dim, k, alpha) = (500usize, 2048usize, 100usize, 1.0f64);
    println!("== E2E pipeline: n={n} D={dim} k={k} alpha={alpha}, {queries} queries/cell ==");
    let corpus = Corpus::generate(&CorpusConfig {
        n,
        dim,
        density: 0.05,
        ..Default::default()
    });
    let engine = SketchEngine::new(alpha, dim, k, 1);

    let mut table = Table::new(&["shards", "batch", "estimator", "qps", "p99 us"]);
    let mut rows = Vec::new();
    for &shards in &[1usize, 2, 4] {
        for &max_batch in &[8usize, 64, 256] {
            for kind in [QueryKind::Oq, QueryKind::Gm] {
                let cfg = PipelineConfig {
                    alpha,
                    k,
                    dim,
                    shards,
                    max_batch,
                    batch_deadline_us: 100,
                    queue_depth: 16_384,
                    ..Default::default()
                };
                let store = engine.sketch_all(corpus.as_slice(), n);
                let coord = Coordinator::start(cfg, store).expect("start");
                let (qps, p99) = run_workload(&coord, n, queries, kind, 7);
                let kind_s = kind.label();
                table.row(vec![
                    format!("{shards}"),
                    format!("{max_batch}"),
                    kind_s.to_string(),
                    format!("{qps:.0}"),
                    format!("{p99:.0}"),
                ]);
                rows.push(Json::obj(vec![
                    ("shards", Json::num(shards as f64)),
                    ("max_batch", Json::num(max_batch as f64)),
                    ("estimator", Json::str(kind_s)),
                    ("qps", Json::num(qps)),
                    ("p99_us", Json::num(p99)),
                ]));
                coord.shutdown();
            }
        }
    }
    table.print();

    // --- TopK plan vs brute-force pair queries ----------------------
    let cfg = PipelineConfig {
        alpha,
        k,
        dim,
        shards: 2,
        max_batch: 64,
        batch_deadline_us: 100,
        queue_depth: 16_384,
        ..Default::default()
    };
    let store = engine.sketch_all(corpus.as_slice(), n);
    let coord = Coordinator::start(cfg, store).expect("start");
    let anchors = (common::reps(60_000) / 600).max(8);
    let (plan_dps, pairs_dps) = run_topk_comparison(&coord, n, anchors, 10);
    println!(
        "\nTopK@10 over {anchors} anchors: plan {plan_dps:.0} distances/s vs \
         pair-queries {pairs_dps:.0} distances/s ({:.1}x)",
        plan_dps / pairs_dps
    );
    println!("{}", coord.metrics().report());
    rows.push(Json::obj(vec![
        ("topk_plan_dps", Json::num(plan_dps)),
        ("topk_pairs_dps", Json::num(pairs_dps)),
    ]));
    coord.shutdown();
    common::dump("e2e_pipeline.json", &rows);

    // Shape: oq must out-serve gm at the same configuration (the whole
    // point), at the largest batch size where estimator cost dominates.
    let qps_of = |kind: &str, shards: f64, batch: f64| {
        rows.iter()
            .find(|r| {
                r.get("estimator").and_then(|e| e.as_str()) == Some(kind)
                    && r.get("shards").and_then(|s| s.as_f64()) == Some(shards)
                    && r.get("max_batch").and_then(|b| b.as_f64()) == Some(batch)
            })
            .unwrap()
            .get("qps")
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let (oq, gm) = (qps_of("oq", 1.0, 256.0), qps_of("gm", 1.0, 256.0));
    assert!(
        oq > gm,
        "oq should out-serve gm at k={k}: {oq:.0} vs {gm:.0} qps"
    );
    println!("\nshape check passed: oq {oq:.0} qps vs gm {gm:.0} qps (1 shard, batch 256)");
    assert!(
        plan_dps > pairs_dps,
        "TopK plan should beat brute-force pair queries: {plan_dps:.0} vs {pairs_dps:.0}"
    );
}
