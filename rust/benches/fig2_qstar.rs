//! Fig 2 — (a) the optimal quantile q*(α); (b) the constant
//! W^α(q*) = (q*-quantile of |S(α,1)|)^α.
//!
//! Paper anchors: q*(0+) = 0.203, q*(1) = 0.5, q*(2) = 0.862.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::estimators::tables;
use stablesketch::util::json::Json;

fn main() {
    println!("== Fig 2: q*(α) and W^α(q*) ==");
    let alphas = common::alpha_grid(0.05);
    let mut table = Table::new(&["alpha", "q*", "W^alpha(q*)"]);
    let mut rows = Vec::new();
    let mut prev_q = 0.0f64;
    for &alpha in &alphas {
        let q = tables::q_star(alpha);
        let w = tables::w_alpha_star(alpha);
        table.row(vec![
            format!("{alpha:.2}"),
            format!("{q:.4}"),
            format!("{w:.4}"),
        ]);
        rows.push(Json::obj(vec![
            ("alpha", Json::num(alpha)),
            ("q_star", Json::num(q)),
            ("w_alpha", Json::num(w)),
        ]));
        assert!(
            q >= prev_q - 0.02,
            "q*(α) must be (weakly) increasing; broke at {alpha}: {q} < {prev_q}"
        );
        prev_q = q;
    }
    table.print();
    common::dump("fig2_qstar.json", &rows);

    // Anchor checks against the paper's quoted values.
    let q0 = tables::q_star(0.05);
    let q1 = tables::q_star(1.0);
    let q2 = tables::q_star(2.0);
    assert!((q0 - 0.203).abs() < 0.02, "q*(0+)≈0.203, got {q0}");
    assert!((q1 - 0.5).abs() < 0.005, "q*(1)=0.5, got {q1}");
    assert!((q2 - 0.862).abs() < 0.005, "q*(2)=0.862, got {q2}");
    println!("\nanchor checks passed: q*(0+)≈{q0:.3}, q*(1)≈{q1:.3}, q*(2)≈{q2:.3}");
}
