//! Fig 4 — relative computational cost: time(gm) / time(oq,c) and
//! time(gm) / time(fp), per estimate, across α and k.
//!
//! This is the paper's headline systems claim: selecting is ~an order of
//! magnitude cheaper than k fractional powers, and the ratio grows with
//! k (the single pow in oq amortizes away). The paper used a *naive*
//! recursive quick-select; we report both the naive variant (faithful
//! reproduction) and the optimized production selector (ablation).

mod common;

use stablesketch::bench_util::{bench, black_box, BenchConfig, Table};
use stablesketch::estimators::quickselect::{quantile_index, select_kth_naive};
use stablesketch::estimators::{
    tables, FractionalPower, GeometricMean, OptimalQuantile, ScaleEstimator,
};
use stablesketch::numerics::Xoshiro256pp;
use stablesketch::stable::StableDist;
use stablesketch::util::json::Json;

fn main() {
    let alphas = [0.5f64, 1.0, 1.5, 2.0];
    let ks = [10usize, 20, 50, 100, 200, 500, 1000];
    let cfg = BenchConfig {
        warmup_batches: 2,
        samples: 9,
        iters_per_batch: 0,
    };
    println!("== Fig 4: relative cost, time(gm)/time(est) per estimate ==");
    let mut table = Table::new(&["alpha", "k", "gm ns", "fp ns", "oq ns", "gm/fp", "gm/oq", "gm/oq-naive"]);
    let mut rows = Vec::new();
    let mut rng = Xoshiro256pp::new(4242);

    for &alpha in &alphas {
        for &k in &ks {
            // Pre-draw a pool of sample vectors so RNG cost is excluded
            // (the paper times only the estimator evaluation).
            let dist = StableDist::new(alpha, 1.0);
            let pool: Vec<Vec<f64>> = (0..64)
                .map(|_| {
                    let mut v = vec![0.0; k];
                    dist.sample_into(&mut rng, &mut v);
                    v
                })
                .collect();
            let gm = GeometricMean::new(alpha, k);
            let fp = FractionalPower::new(alpha, k);
            let oq = OptimalQuantile::new(alpha, k);
            let mut cursor = 0usize;
            let mut buf = vec![0.0; k];
            let mut run = |est: &dyn ScaleEstimator| {
                let m = bench("est", &cfg, || {
                    cursor = (cursor + 1) & 63;
                    buf.copy_from_slice(&pool[cursor]);
                    black_box(est.estimate(&mut buf))
                });
                m.ns_per_op_median
            };
            let gm_ns = run(&gm);
            let fp_ns = run(&fp);
            let oq_ns = run(&oq);
            // The paper's own naive selector, timed end-to-end.
            let q = tables::q_star(alpha);
            let idx = quantile_index(q, k);
            let scale = 1.0; // coefficient multiply is identical either way
            let naive_ns = {
                let m = bench("naive", &cfg, || {
                    cursor = (cursor + 1) & 63;
                    buf.copy_from_slice(&pool[cursor]);
                    for x in buf.iter_mut() {
                        *x = x.abs();
                    }
                    let sel = select_kth_naive(&buf, idx);
                    black_box(sel.powf(alpha) * scale)
                });
                m.ns_per_op_median
            };
            table.row(vec![
                format!("{alpha:.1}"),
                format!("{k}"),
                format!("{gm_ns:.0}"),
                format!("{fp_ns:.0}"),
                format!("{oq_ns:.0}"),
                format!("{:.2}", gm_ns / fp_ns),
                format!("{:.2}", gm_ns / oq_ns),
                format!("{:.2}", gm_ns / naive_ns),
            ]);
            rows.push(Json::obj(vec![
                ("alpha", Json::num(alpha)),
                ("k", Json::num(k as f64)),
                ("gm_ns", Json::num(gm_ns)),
                ("fp_ns", Json::num(fp_ns)),
                ("oq_ns", Json::num(oq_ns)),
                ("oq_naive_ns", Json::num(naive_ns)),
                ("ratio_gm_fp", Json::num(gm_ns / fp_ns)),
                ("ratio_gm_oq", Json::num(gm_ns / oq_ns)),
                ("ratio_gm_oq_naive", Json::num(gm_ns / naive_ns)),
            ]));
        }
    }
    table.print();
    common::dump("fig4_cost.json", &rows);

    // Paper shape: (A) gm ≈ fp in cost; (B) gm/oq grows with k and is
    // large (paper: ~an order of magnitude) at k ≥ 100.
    let find = |a: f64, k: usize| {
        rows.iter()
            .find(|r| {
                r.get("alpha").unwrap().as_f64() == Some(a)
                    && r.get("k").unwrap().as_f64() == Some(k as f64)
            })
            .unwrap()
            .clone()
    };
    let r100 = find(1.0, 100).get("ratio_gm_oq").unwrap().as_f64().unwrap();
    let r10 = find(1.0, 10).get("ratio_gm_oq").unwrap().as_f64().unwrap();
    let gm_fp = find(1.0, 100).get("ratio_gm_fp").unwrap().as_f64().unwrap();
    assert!(r100 > r10, "gm/oq must grow with k: {r10} -> {r100}");
    assert!(r100 > 3.0, "gm/oq at k=100 should be large, got {r100}");
    assert!(gm_fp > 0.5 && gm_fp < 2.0, "gm and fp should cost alike, got {gm_fp}");
    println!("\nshape checks passed: gm/oq k=10 → {r10:.1}, k=100 → {r100:.1}; gm/fp ≈ {gm_fp:.2}");
}
