//! Fig 1 — Cramér–Rao efficiencies of the gm / hm / fp / oq (and
//! median) estimators as functions of α.
//!
//! Paper shape to reproduce: oq ≈ gm for α < 1; oq clearly above gm for
//! α > 1; oq above fp on 1 < α ≤ 1.8; fp wins near α = 2; hm only
//! competitive at small α.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::estimators::{cramer_rao_bound_factor, efficiency_curve, EstimatorKind};
use stablesketch::util::json::Json;

fn main() {
    let alphas = common::alpha_grid(0.1);
    let kinds = [
        EstimatorKind::GeometricMean,
        EstimatorKind::HarmonicMean,
        EstimatorKind::FractionalPower,
        EstimatorKind::OptimalQuantile,
        EstimatorKind::Median,
    ];
    println!("== Fig 1: Cramér–Rao efficiencies (1.0 = statistically optimal) ==");
    let mut table = Table::new(&["alpha", "CR-var", "gm", "hm", "fp", "oq", "median"]);
    let mut rows = Vec::new();
    let curves: Vec<Vec<(f64, f64)>> = kinds
        .iter()
        .map(|&k| efficiency_curve(k, &alphas))
        .collect();
    for (ai, &alpha) in alphas.iter().enumerate() {
        let cr = cramer_rao_bound_factor(alpha);
        let cells: Vec<String> = curves
            .iter()
            .map(|c| {
                let e = c[ai].1;
                if e.is_nan() {
                    "--".to_string()
                } else {
                    format!("{e:.3}")
                }
            })
            .collect();
        let mut row = vec![format!("{alpha:.1}"), format!("{cr:.3}")];
        row.extend(cells.clone());
        table.row(row);
        rows.push(Json::obj(vec![
            ("alpha", Json::num(alpha)),
            ("cr_bound_factor", Json::num(cr)),
            ("gm", Json::num(curves[0][ai].1)),
            ("hm", Json::num(curves[1][ai].1)),
            ("fp", Json::num(curves[2][ai].1)),
            ("oq", Json::num(curves[3][ai].1)),
            ("median", Json::num(curves[4][ai].1)),
        ]));
    }
    table.print();
    println!(
        "note: the Fisher information (CR-var column) is numerically unreliable for\n\
         α ≲ 0.15 — the stable density is a near-delta peak there (f(0) = Γ(1+1/α)/π\n\
         grows super-exponentially) and the score integration loses the peak.\n\
         Estimator-vs-estimator comparisons are unaffected (they share the CR factor);\n\
         the exact V_hm(0.1) = 1.022 anchor implies CR-var(0.1) ≈ 1.0 (hm → optimal\n\
         as α → 0+, paper §2.1)."
    );
    common::dump("fig1_efficiency.json", &rows);

    // Paper-shape assertions (who wins where):
    let eff = |k: EstimatorKind, a: f64| efficiency_curve(k, &[a])[0].1;
    assert!(eff(EstimatorKind::OptimalQuantile, 1.5) > eff(EstimatorKind::GeometricMean, 1.5));
    assert!(eff(EstimatorKind::OptimalQuantile, 1.5) > eff(EstimatorKind::FractionalPower, 1.5));
    assert!(eff(EstimatorKind::FractionalPower, 2.0) > eff(EstimatorKind::OptimalQuantile, 2.0));
    println!("\nshape checks passed: oq>gm and oq>fp at α=1.5; fp>oq at α=2");
}
