//! Network serving bench: what does the wire cost?
//!
//! Compares the same pair/TopK workloads (a) in-process through
//! `Coordinator::query_plan` and (b) over loopback TCP through
//! `SketchClient`, at several pipeline depths. The delta is the
//! protocol + socket overhead; deeper pipelines amortize it, which is
//! the case for batching remote plans.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::coordinator::{Coordinator, Query, QueryKind};
use stablesketch::numerics::{Rng, Xoshiro256pp};
use stablesketch::server::{ServerConfig, SketchClient, SketchServer};
use stablesketch::sketch::SketchEngine;
use stablesketch::simul::{Corpus, CorpusConfig};
use stablesketch::util::config::PipelineConfig;
use stablesketch::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn make_plan(rng: &mut Xoshiro256pp, n: u64, depth: usize) -> Vec<Query> {
    (0..depth)
        .map(|t| {
            if t % 8 == 7 {
                Query::TopK {
                    i: rng.below(n) as u32,
                    m: 8,
                    kind: QueryKind::Oq,
                }
            } else {
                Query::Pair {
                    i: rng.below(n) as u32,
                    j: rng.below(n) as u32,
                    kind: QueryKind::Oq,
                }
            }
        })
        .collect()
}

fn main() {
    let n = 2_000usize;
    let queries = common::reps(20_000);
    let corpus = Corpus::generate(&CorpusConfig {
        n,
        dim: 1024,
        density: 0.05,
        ..Default::default()
    });
    let cfg = PipelineConfig {
        alpha: 1.0,
        k: 64,
        dim: corpus.dim,
        shards: 2,
        ..Default::default()
    };
    let engine = SketchEngine::new(cfg.alpha, cfg.dim, cfg.k, cfg.seed);
    let store = engine.sketch_all(corpus.as_slice(), corpus.n);
    let coord = Arc::new(Coordinator::start(cfg, store).expect("coordinator"));
    let server = SketchServer::start(coord.clone(), "127.0.0.1:0", ServerConfig::default())
        .expect("server");
    let addr = server.local_addr().to_string();
    let mut client =
        SketchClient::connect_with_retry(&addr, 10, Duration::from_millis(20)).expect("connect");

    let mut table = Table::new(&["path", "pipeline_depth", "qps", "us_per_query"]);
    let mut rows: Vec<Json> = Vec::new();
    for depth in [1usize, 16, 256] {
        for path in ["in_process", "loopback_tcp"] {
            let mut rng = Xoshiro256pp::new(0xBE9C ^ depth as u64);
            let t0 = Instant::now();
            let mut done = 0usize;
            while done < queries {
                let plan = make_plan(&mut rng, n as u64, depth.min(queries - done));
                let sent = plan.len();
                match path {
                    "in_process" => {
                        coord.query_plan(plan).expect("plan");
                    }
                    _ => {
                        client.query_plan(&plan).expect("remote plan");
                    }
                }
                done += sent;
            }
            let dt = t0.elapsed().as_secs_f64();
            let qps = done as f64 / dt;
            table.row(vec![
                path.to_string(),
                depth.to_string(),
                format!("{qps:.0}"),
                format!("{:.2}", 1e6 * dt / done as f64),
            ]);
            rows.push(Json::obj(vec![
                ("path", Json::str(path.to_string())),
                ("pipeline_depth", Json::num(depth as f64)),
                ("qps", Json::num(qps)),
            ]));
        }
    }
    table.print();
    common::dump("net_loopback.jsonl", &rows);
    server.shutdown();
}
