//! Fig 7 — empirical right tail probabilities
//! Pr( d̂ ≥ (1+ε)·d ) for gm / fp / oq,c at α ∈ {0.5, 1, 1.5, 2},
//! k ∈ {20, 50}, with the Lemma-3 bound overlaid for oq.
//!
//! Paper shape: for α > 1 the fp estimator's right tail is dramatically
//! heavier (its moments barely exceed order 2 near α = 2); oq
//! consistently dominates gm and fp for α > 1. The theoretical bound
//! must lie above the empirical oq curve.

mod common;

use stablesketch::bench_util::Table;
use stablesketch::estimators::*;
use stablesketch::simul::mc::{right_tail_curve, McConfig};
use stablesketch::util::json::Json;

fn main() {
    let reps = common::reps(200_000);
    let alphas = [0.5f64, 1.0, 1.5, 1.9, 2.0];
    let ks = [20usize, 50];
    let epsilons: Vec<f64> = vec![0.25, 0.5, 0.75, 1.0, 1.5, 2.0];
    println!("== Fig 7: right tail Pr(d̂ ≥ (1+ε)d)  (reps={reps}) ==");
    let mut rows = Vec::new();
    for &alpha in &alphas {
        for &k in &ks {
            println!("\n-- alpha = {alpha}, k = {k} --");
            let cfg = McConfig {
                reps,
                seed: 0x7A11 ^ ((alpha * 100.0) as u64) << 10 ^ k as u64,
                d_true: 1.0,
            };
            let gm = right_tail_curve(&GeometricMean::new(alpha, k), &cfg, &epsilons);
            let fp = right_tail_curve(&FractionalPower::new(alpha, k), &cfg, &epsilons);
            let oq = right_tail_curve(&OptimalQuantile::new(alpha, k), &cfg, &epsilons);
            let q_star = tables::q_star(alpha);
            let mut table = Table::new(&["eps", "gm", "fp", "oq,c", "oq bound"]);
            for (i, &eps) in epsilons.iter().enumerate() {
                let tc = tail_bounds::tail_constants(alpha, q_star, eps);
                let bound = (-(k as f64) * eps * eps / tc.g_right).exp();
                table.row(vec![
                    format!("{eps:.2}"),
                    format!("{:.5}", gm[i].prob),
                    format!("{:.5}", fp[i].prob),
                    format!("{:.5}", oq[i].prob),
                    format!("{bound:.5}"),
                ]);
                rows.push(Json::obj(vec![
                    ("alpha", Json::num(alpha)),
                    ("k", Json::num(k as f64)),
                    ("eps", Json::num(eps)),
                    ("p_gm", Json::num(gm[i].prob)),
                    ("p_fp", Json::num(fp[i].prob)),
                    ("p_oq", Json::num(oq[i].prob)),
                    ("oq_bound", Json::num(bound)),
                ]));
            }
            table.print();
        }
    }
    common::dump("fig7_tails.json", &rows);

    let cell = |a: f64, k: usize, eps: f64, key: &str| {
        rows.iter()
            .find(|r| {
                r.get("alpha").unwrap().as_f64() == Some(a)
                    && r.get("k").unwrap().as_f64() == Some(k as f64)
                    && r.get("eps").unwrap().as_f64() == Some(eps)
            })
            .unwrap()
            .get(key)
            .unwrap()
            .as_f64()
            .unwrap()
    };
    // Shape: for α > 1 (but below 2 — at exactly α = 2 the samples are
    // Gaussian, no heavy tail exists, and fp degenerates to an
    // arithmetic-mean-like estimator with *light* tails; the paper's
    // fp-tail pathology concerns α approaching 2 from below),
    // fp's right tail is heavier than oq's.
    // fp's tail decays polynomially (it visibly *flattens* in the
    // tables above) while oq's decays exponentially — so the dominance
    // is asserted in the deep tail (ε = 2), where fp is 2–20× worse.
    for &a in &[1.5, 1.9] {
        for &k in &ks {
            assert!(
                cell(a, k, 2.0, "p_oq") < cell(a, k, 2.0, "p_fp") + 2.0 / reps as f64,
                "oq !< fp deep tail at alpha={a} k={k}"
            );
            assert!(
                cell(a, k, 0.5, "p_oq") < cell(a, k, 0.5, "p_gm") * 1.2,
                "oq tail way above gm at alpha={a} k={k}"
            );
        }
    }
    // The Lemma-3 bound holds empirically (with slack for MC noise).
    for &a in &alphas {
        for &k in &ks {
            for &eps in &epsilons {
                let emp = cell(a, k, eps, "p_oq");
                let bound = cell(a, k, eps, "oq_bound");
                assert!(
                    emp <= bound * 1.25 + 5.0 / reps as f64,
                    "bound violated: alpha={a} k={k} eps={eps}: {emp} > {bound}"
                );
            }
        }
    }
    println!("\nshape checks passed: fp heavy right tail for α>1; Lemma 3 bound ≥ empirical");
}
