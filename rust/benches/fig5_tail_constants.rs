//! Fig 5 — tail bound constants G_{R,q}(ε) and G_{L,q}(ε) (Lemma 3),
//! for the optimal quantile estimator (upper panels) and the sample
//! median baseline (lower panels), at α ∈ {0.5, 1, 1.5, 2}.
//!
//! Paper shape: constants increase with ε on the right tail, G_L < G_R,
//! oq constants below the median's, and G_R(0.5) ≈ 5–9 (driving the
//! k ≈ 120–215 sample-size headline).

mod common;

use stablesketch::bench_util::Table;
use stablesketch::estimators::{tables, tail_bounds};
use stablesketch::util::json::Json;

fn main() {
    let alphas = [0.5f64, 1.0, 1.5, 2.0];
    let epsilons: Vec<f64> = (1..=19).map(|i| i as f64 * 0.05).collect();
    println!("== Fig 5: tail-bound constants (lower = stronger bound) ==");
    let mut rows = Vec::new();
    for &alpha in &alphas {
        let q_star = tables::q_star(alpha);
        println!("\n-- alpha = {alpha} (q* = {q_star:.3}) --");
        let mut table = Table::new(&["eps", "G_R(q*)", "G_L(q*)", "G_R(0.5)", "G_L(0.5)"]);
        for &eps in &epsilons {
            let oq = tail_bounds::tail_constants(alpha, q_star, eps);
            let med = tail_bounds::tail_constants(alpha, 0.5, eps);
            table.row(vec![
                format!("{eps:.2}"),
                format!("{:.2}", oq.g_right),
                format!("{:.2}", oq.g_left),
                format!("{:.2}", med.g_right),
                format!("{:.2}", med.g_left),
            ]);
            rows.push(Json::obj(vec![
                ("alpha", Json::num(alpha)),
                ("eps", Json::num(eps)),
                ("g_right_oq", Json::num(oq.g_right)),
                ("g_left_oq", Json::num(oq.g_left)),
                ("g_right_median", Json::num(med.g_right)),
                ("g_left_median", Json::num(med.g_left)),
            ]));
        }
        table.print();
        // sample-size planner corollary (paper §3.4)
        let k_half = tail_bounds::sample_size_fraction(alpha, q_star, 0.5, 10.0, 0.05);
        let k_one = tail_bounds::sample_size_fraction(alpha, q_star, 1.0, 10.0, 0.05);
        println!("   ⇒ k(eps=0.5) = {k_half}, k(eps=1.0) = {k_one}  (paper: 120–215 / 40–65)");
    }
    common::dump("fig5_tail_constants.json", &rows);

    // Shape checks.
    for &alpha in &alphas {
        let q_star = tables::q_star(alpha);
        let tc = tail_bounds::tail_constants(alpha, q_star, 0.5);
        assert!(tc.g_left < tc.g_right, "G_L < G_R violated at alpha={alpha}");
        assert!(
            tc.g_right > 3.0 && tc.g_right < 12.0,
            "G_R(0.5)≈5–9; got {} at alpha={alpha}",
            tc.g_right
        );
        if (alpha - 1.0).abs() > 0.25 {
            let med = tail_bounds::tail_constants(alpha, 0.5, 0.5);
            assert!(
                tc.g_right <= med.g_right + 1e-9,
                "oq must beat median at alpha={alpha}"
            );
        }
    }
    println!("\nshape checks passed");
}
